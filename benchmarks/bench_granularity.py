"""Paper Fig. 7 / Table III: accuracy across weight x psum quantization
granularities, one-stage QAT. Validates the paper's ordering:

  column/column >= layer/column >= array/array >= layer/layer
  and column/column closest to the no-PSQ ceiling.
"""
from __future__ import annotations

import time

from repro.core.granularity import Granularity as G

from .common import _data, make_cim, train_qat

COMBOS = [
    ("layer/layer", G.LAYER, G.LAYER),
    ("array/array", G.ARRAY, G.ARRAY),
    ("layer/column (Saxena'23)", G.LAYER, G.COLUMN),
    ("column/column (ours)", G.COLUMN, G.COLUMN),
]


def run(steps=150, seed=0, csv=None):
    data = _data(seed)
    rows = []
    # no-PSQ ceiling with column weights (paper's dashed line)
    t0 = time.time()
    ceil = train_qat(make_cim(G.COLUMN, G.COLUMN, psum_quant=False),
                     steps=steps, seed=seed, data=data)
    rows.append(("column w/o PSQ (ceiling)", ceil["acc"], ceil["train_time"]))
    for name, gw, gp in COMBOS:
        r = train_qat(make_cim(gw, gp), steps=steps, seed=seed, data=data)
        rows.append((name, r["acc"], r["train_time"]))
    print("\n== Fig.7 / Table III: granularity vs accuracy (one-stage QAT) ==")
    for name, acc, tt in rows:
        line = f"granularity,{name},acc={acc:.4f},train_s={tt:.1f}"
        print(line)
        if csv is not None:
            csv.append(line)
    ours = dict((r[0], r[1]) for r in rows)
    assert ours["column/column (ours)"] >= ours["layer/layer"] - 0.02, rows
    return rows


if __name__ == "__main__":
    run()
