"""Conv-kernel microbenchmark: the fused Pallas conv deploy path vs the
emulate grouped-conv path (the paper's dominant ResNet workload).

On this CPU box the Pallas kernel runs in interpret mode, so wall-clock
favors XLA — the meaningful numbers are correctness (deploy == emulate)
and the HBM-traffic model: the emulate path tiles the activation
channel-slices ``n_split``x into the group axis AND round-trips the full
(B, H', W', S, kt, C_out) partial-sum tensor through HBM before ADC
quantization; the fused kernel reads int8 patches once per split via its
BlockSpec index map and quantizes each array-tile accumulator in VMEM
(DESIGN.md §3, §7).
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DeployArtifact, QuantConv2d, conv2d
from repro.core import CIMConfig, conv_tiling
from repro.kernels.ref import conv_pads

from .bench_kernel import dtype_bytes, plane_stream_bytes


def conv_traffic_model(b, h, w, c_out, kh, kw, stride, padding, tiling,
                       *, act_dtype="int8", pack_dtype="int8"):
    """HBM bytes for one conv layer: fused deploy kernel vs the naive
    (emulate) grouped-conv pipeline. ``tiling`` is the ArrayTiling from
    ``conv_tiling`` (the kernel's actual geometry — not re-derived here).
    Digit-plane bytes follow the streamed storage (``plane_stream_bytes``
    over the packed ``c_per_array`` axis: nibble-packed uint8 for even
    cpa int4, int8-width otherwise) plus the uint8 occupancy maps.
    Returns (fused, naive, psum_rt) where psum_rt is the partial-sum
    round-trip the fusion eliminates (2 * B*H'*W' * S * kt * C_out * 4)."""
    n_split, k_tiles, rows = tiling.n_split, tiling.k_tiles, tiling.array_rows
    cpa = rows // (kh * kw)
    pads = conv_pads(h, w, kh, kw, stride, padding)
    ho = (h + pads[0][0] + pads[0][1] - kh) // stride + 1
    wo = (w + pads[1][0] + pads[1][1] - kw) // stride + 1
    m = b * ho * wo
    ba, bd = dtype_bytes(act_dtype), plane_stream_bytes(pack_dtype, cpa)
    scales = 2 * n_split * k_tiles * c_out * 4
    occ = n_split * k_tiles * c_out                 # uint8 skip maps
    fused = int(m * k_tiles * rows * ba             # patches, read once
                + n_split * k_tiles * rows * c_out * bd + occ
                + m * c_out * 4 + scales)
    psum_rt = 2 * m * n_split * k_tiles * c_out * 4
    naive = int(2 * b * h * w * n_split * k_tiles * cpa * 4  # tiled acts w+r
                + n_split * k_tiles * rows * c_out * 4       # f32 weights
                + psum_rt
                + m * c_out * 4 + scales)
    return fused, naive, psum_rt


def run(csv=None):
    b, hw, c_in, c_out, kh = 4, 16, 32, 64, 3
    stride, padding = 1, "SAME"
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=128, array_cols=128,
                    act_signed=False)
    key = jax.random.PRNGKey(0)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1),
                                      (b, hw, hw, c_in)))
    layer = QuantConv2d(kh, kh, c_in, c_out, cfg, stride=stride,
                        padding=padding).init(key).calibrate(x)
    # pack through a saved+reloaded DeployArtifact so the benchmarked
    # bytes are exactly what a served model loads (no hand-rolled
    # packing drift between bench and production)
    with tempfile.TemporaryDirectory() as d:
        layer.pack().save(d)
        art = DeployArtifact.load(d)
    dp = art.params

    variants = (
        ("emulate_groupconv", layer.params, cfg),
        ("deploy_jnp_ref", dp, art.config.replace(mode="ref")),
        ("deploy_pallas_interpret", dp,
         art.config.replace(use_kernel=True)),
    )
    out0 = None
    results = []
    for name, params, c in variants:
        fn = jax.jit(lambda x_, params=params, c=c: conv2d(
            x_, params, c, stride=stride, padding=padding,
            compute_dtype=jnp.float32))
        out = fn(x)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(x)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        results.append((name, us))
        if out0 is None:
            out0 = out
        else:
            np.testing.assert_allclose(np.asarray(out0), np.asarray(out),
                                       rtol=1e-4, atol=1e-4)

    t, _ = conv_tiling(kh, kh, c_in, c_out, cfg.array_rows, cfg.array_cols,
                       cfg.weight_bits, cfg.cell_bits)
    print("\n== conv kernel microbench (CPU; kernel in interpret mode) ==")
    for name, us in results:
        line = f"conv_kernel,{name},us_per_call={us:.0f}"
        print(line)
        if csv is not None:
            csv.append(line)
    for pack in ("int8", "int4"):
        fused, naive, psum_rt = conv_traffic_model(
            b, hw, hw, c_out, kh, kh, stride, padding, t, pack_dtype=pack)
        line = (f"conv_kernel,hbm_traffic_model,pack={pack},"
                f"fused_bytes={fused},naive_bytes={naive},"
                f"psum_roundtrip_bytes={psum_rt},"
                f"saving={naive/fused:.2f}x")
        print(line)
        if csv is not None:
            csv.append(line)

    # -- measured, not modeled: v4 int4 plane bytes vs the v3 layout ----
    # Pack the same layer with pack_dtype='int4' and count the bytes the
    # loaded artifact actually holds (nibble-packed uint8 planes + uint8
    # occupancy maps) against what the v3 layout streamed for the same
    # planes (dense int4 upcast to int8 on the wire).
    cfg4 = cfg.replace(pack_dtype="int4")
    layer4 = QuantConv2d(kh, kh, c_in, c_out, cfg4, stride=stride,
                         padding=padding).init(key).calibrate(x)
    with tempfile.TemporaryDirectory() as d:
        layer4.pack().save(d)
        art4 = DeployArtifact.load(d)
    digits = art4.params["w_digits"]
    occ = np.asarray(art4.params["w_occ"])
    assert digits.dtype == jnp.uint8, "int4 conv planes should nibble-pack"
    v4_bytes = int(digits.size) + occ.size          # uint8: 1 B/element
    v3_bytes = int(digits.size) * 2                 # logical digits @ int8
    y4 = jax.jit(lambda x_: conv2d(
        x_, art4.params, art4.config.replace(use_kernel=True),
        stride=stride, padding=padding, compute_dtype=jnp.float32))(x)
    y4r = conv2d(x, art4.params, art4.config.replace(mode="ref"),
                 stride=stride, padding=padding, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y4r),
                               rtol=1e-4, atol=1e-4)
    line = (f"conv_kernel,int4_plane_bytes,v3_streamed={v3_bytes},"
            f"v4_packed={v4_bytes},reduction={v3_bytes/v4_bytes:.2f}x,"
            f"occupied_frac={occ.mean():.3f}")
    print(line)
    if csv is not None:
        csv.append(line)
    assert v3_bytes / v4_bytes >= 1.8, \
        "nibble packing must cut int4 plane bytes >= 1.8x vs the v3 wire"
    return results


if __name__ == "__main__":
    run()
