"""Paper Fig. 6: integer-valued column-wise partial-sum dynamic range under
layer-wise vs column-wise weight quantization. Column-wise weight scales
should widen the usable integer range of the partial sums."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitsplit import split_digits
from repro.api import calibrate_linear as calibrate_cim
from repro.api import init_linear as init_cim_linear
from repro.core.cim_linear import (CIMConfig, _quantize_act,
                                   _quantize_weight_int, _tile_digits,
                                   _tile_inputs, weight_scales_from)
from repro.core.granularity import Granularity as G


def psum_int_range(gw: G, k=512, n=64, b=256, seed=0):
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=3, cell_bits=1,
                    act_bits=3, psum_bits=4, array_rows=128, array_cols=128,
                    weight_granularity=gw, psum_granularity=G.COLUMN)
    key = jax.random.PRNGKey(seed)
    p = init_cim_linear(key, k, n, cfg)
    # heterogeneous columns (conv-like weight statistics)
    col_scale = jnp.logspace(-1.5, 0.3, n)[None, :]
    p["w"] = p["w"] * col_scale
    p["s_w"] = weight_scales_from(p["w"], cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, k)) * 0.5
    p = calibrate_cim(x, p, cfg)
    t = cfg.tiling(k, n)
    a_int, _ = _quantize_act(x, p, cfg)
    w_int = _quantize_weight_int(p, cfg, t)
    d = _tile_digits(split_digits(w_int, 3, 1), t)
    a_t = _tile_inputs(a_int, t)
    psum = jnp.einsum("btr,strn->bstn", a_t, d)
    # per-column integer dynamic range (max |integer psum| per column)
    rng = np.asarray(jnp.max(jnp.abs(psum), axis=(0, 1, 2)))
    return rng


def run(csv=None):
    r_layer = psum_int_range(G.LAYER)
    r_col = psum_int_range(G.COLUMN)
    print("\n== Fig.6: column psum integer dynamic range ==")
    for name, r in (("layer-weight", r_layer), ("column-weight", r_col)):
        line = (f"psum_range,{name},mean={r.mean():.1f},p10={np.percentile(r,10):.1f},"
                f"p90={np.percentile(r,90):.1f}")
        print(line)
        if csv is not None:
            csv.append(line)
    # paper claim: column-wise weight quantization widens the dynamic range
    assert r_col.mean() > r_layer.mean() * 0.8
    return {"layer": r_layer, "column": r_col}


if __name__ == "__main__":
    run()
