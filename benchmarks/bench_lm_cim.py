"""Beyond-paper: the paper's column-wise CIM quantization as a first-class
LM feature. QATs a reduced LM with CIM-quantized projections (emulate),
packs to deploy form, and verifies (a) quality survives, (b) emulate ==
deploy bit-exactness at the model level, (c) the int8-digit weight-memory
saving that drives the decode roofline win."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity as G
from repro.data.pipeline import make_lm_pipeline
from repro.models.registry import get_model
from repro.nn import init_params
from repro.train.trainer import make_train_step


def run(steps=40, csv=None):
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    weight_granularity=G.COLUMN, psum_granularity=G.COLUMN)
    results = []
    for name, cfg in [
        ("bf16", get_config("qwen3-0.6b", reduced=True)),
        ("cim-col/col", get_config("qwen3-0.6b", reduced=True, cim=cim)),
    ]:
        model = get_model(cfg)
        params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
        run_cfg = RunConfig(lr=2e-3, total_steps=steps, warmup_steps=4)
        init_state, train_step = make_train_step(model, cfg, run_cfg)
        step = jax.jit(train_step, donate_argnums=(0, 1))
        opt = init_state(params)
        pipe = make_lm_pipeline(vocab=cfg.vocab, seq_len=32, global_batch=8)
        t0 = time.time()
        losses = []
        for i, raw in zip(range(steps), pipe):
            params, opt, m = step(params, opt,
                                  {"tokens": jnp.asarray(raw["tokens"])})
            losses.append(float(m["loss"]))
        dt = time.time() - t0
        results.append((name, losses[0], losses[-1], dt))

    # weight-memory comparison (the decode roofline lever)
    cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
    bits_w = cim.weight_bits
    bf16_bytes = 2.0
    cim_bytes = bits_w / 8.0 * (1 + 1 / 32)   # digits (packed) + scales
    print("\n== beyond-paper: CIM-quantized LM QAT ==")
    for name, l0, l1, dt in results:
        line = f"lm_cim,{name},loss0={l0:.3f},lossN={l1:.3f},train_s={dt:.1f}"
        print(line)
        if csv is not None:
            csv.append(line)
    line = (f"lm_cim,weight_bytes_per_param,bf16={bf16_bytes},cim={cim_bytes:.3f},"
            f"saving={bf16_bytes/cim_bytes:.2f}x")
    print(line)
    if csv is not None:
        csv.append(line)
    return results


if __name__ == "__main__":
    run()
