"""Paper Fig. 10: inference accuracy under log-normal memory-cell variation
across quantization schemes. Validates the robustness ordering: models with
column-wise scales degrade more gracefully."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.granularity import Granularity as G

from .common import _data, evaluate, make_cim, resnet_cfg, train_qat

SIGMAS = (0.0, 0.1, 0.2, 0.3, 0.4)


def run(steps=150, seed=0, csv=None):
    data = _data(seed)
    schemes = [
        ("layer/layer", G.LAYER, G.LAYER),
        ("layer/column (Saxena'23)", G.LAYER, G.COLUMN),
        ("column/column (ours)", G.COLUMN, G.COLUMN),
    ]
    print("\n== Fig.10: accuracy vs cell-variation sigma ==")
    (xtr, ytr), (xte, yte) = data
    out = {}
    for name, gw, gp in schemes:
        r = train_qat(make_cim(gw, gp), steps=steps, seed=seed, data=data)
        accs = []
        for sigma in SIGMAS:
            cfg = resnet_cfg(make_cim(gw, gp, variation_std=sigma))
            acc = evaluate(r["params"], r["state"], cfg, xte, yte,
                           variation_key=(jax.random.PRNGKey(7)
                                          if sigma > 0 else None))
            accs.append(acc)
        out[name] = accs
        line = ("variation," + name + ","
                + ",".join(f"s{int(s*10)}={a:.3f}"
                           for s, a in zip(SIGMAS, accs)))
        print(line)
        if csv is not None:
            csv.append(line)
    return out


if __name__ == "__main__":
    run()
