"""Paper Fig. 10: inference accuracy under log-normal memory-cell variation
across quantization schemes — run as a Monte-Carlo sweep **on the fused
Pallas deploy kernels** (``repro.eval.robustness``), the configuration that
would actually ship, not the n_split-replicated emulate fallback.

For each scheme: short QAT, pack to int digit planes once, then an
N-sample sigma-grid accuracy/logit-error sweep (lazy per-sample noise, no
re-packing, one jitted step for the whole grid). The column/column scheme
additionally prints per-layer error attribution: which layers' columns
absorb the conductance drift and which let it through.

Validates the robustness ordering: models with column-wise scales degrade
more gracefully."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.api import pack_model
from repro.core.granularity import Granularity as G
from repro.eval import robustness
from repro.models import resnet

from .common import _data, make_cim, resnet_cfg, train_qat

SIGMAS = (0.0, 0.1, 0.2, 0.3, 0.4)
N_SAMPLES = 4
ATTRIB_SIGMA = 0.3


def run(steps=150, seed=0, csv=None, n_samples=N_SAMPLES, n_eval=256):
    data = _data(seed)
    schemes = [
        ("layer/layer", G.LAYER, G.LAYER),
        ("layer/column (Saxena'23)", G.LAYER, G.COLUMN),
        ("column/column (ours)", G.COLUMN, G.COLUMN),
    ]
    print("\n== Fig.10: Monte-Carlo accuracy vs cell-variation sigma "
          "(deploy kernels) ==")
    (xtr, ytr), (xte, yte) = data
    xte, yte = xte[:n_eval], yte[:n_eval]
    key = jax.random.PRNGKey(7)
    out = {}
    attrib_target = None          # (name, packed, state, dcfg) of "ours"
    for name, gw, gp in schemes:
        cim = make_cim(gw, gp)
        r = train_qat(cim, steps=steps, seed=seed, data=data)
        # pack once (the generic DeployArtifact tree walk); every MC
        # sample is a lazy perturbation of these planes
        cfg_e = resnet_cfg(cim)
        packed = pack_model(r["params"], cfg_e.cim)
        dcfg = dataclasses.replace(cfg_e, cim=cim.replace(mode="deploy"))
        sweep = robustness.monte_carlo_resnet(
            packed, r["state"], dcfg, xte, yte,
            key=key, sigmas=SIGMAS, n_samples=n_samples)
        out[name] = sweep
        if gw == G.COLUMN and gp == G.COLUMN:
            attrib_target = (name, packed, r["state"], dcfg)
        line = ("variation," + name + ","
                + ",".join(f"s{int(s * 10)}={m:.3f}±{sd:.3f}"
                           for s, m, sd in zip(SIGMAS, sweep.acc_mean,
                                               sweep.acc_std)))
        print(line)
        err_line = ("variation_err," + name + ","
                    + ",".join(f"s{int(s * 10)}={e:.3f}"
                               for s, e in zip(SIGMAS, sweep.logit_err_mean)))
        print(err_line)
        if csv is not None:
            csv.append(line)
            csv.append(err_line)

    # per-layer attribution for the paper's scheme at a mid-grid sigma
    assert attrib_target is not None, \
        "schemes must include the (COLUMN, COLUMN) entry for attribution"
    name, packed, state, dcfg = attrib_target
    print(f"\n-- per-layer attribution, {name}, sigma={ATTRIB_SIGMA} --")
    attrib = robustness.per_layer_attribution(
        packed, state, dcfg, jax.numpy.asarray(xte[:64]),
        key=key, sigma=ATTRIB_SIGMA)
    worst = sorted(attrib, key=lambda a: -a.rel_err)[:5]
    for a in attrib:
        flag = " <- worst" if a in worst[:1] else ""
        print(f"  {a.name:12s} rel_err={a.rel_err:.3f} "
              f"median_col={a.median_col_err:.3f} "
              f"worst_col=#{a.worst_col}({a.worst_col_err:.3f}){flag}")
    if csv is not None:
        for a in worst:
            csv.append(f"variation_layer,{a.name},rel={a.rel_err:.3f},"
                       f"worst_col={a.worst_col}:{a.worst_col_err:.3f}")
    return out


if __name__ == "__main__":
    run()
