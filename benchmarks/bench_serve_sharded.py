"""Column-parallel serving benchmark: tokens/sec and per-device HBM bytes
vs device count (DESIGN.md §10).

Packs a reduced LM into a ``DeployArtifact`` once, then serves the same
artifact on 1-, 2-, ... up to ``len(jax.devices())``-device ``("model",)``
meshes through ``engine_from_artifact`` — the exact path
``launch/serve.py --mesh`` takes. Two numbers per point:

* **tokens/sec** — measured lockstep ``generate_batch`` throughput. On a
  real multi-chip host this scales with device count; on an emulated CPU
  mesh (``--xla_force_host_platform_device_count=N``) the devices
  timeshare one socket, so the meaningful check is that sharding does not
  collapse throughput while per-device bytes drop.
* **per-device plane bytes** — analytic, extending the §7 traffic model:
  each device holds ``n_padded/D`` of every layer's packed digit-plane
  columns plus its slice of the full-column scales; ragged layers charge
  the padded shard (the kernel's last-shard padding rule). Replicated
  bytes (embeddings, norms, non-column scales) are reported separately.

The curve is served twice — ``pack_dtype='int8'`` and ``'int4'``. The
int4 points stream layout-v4 nibble-packed planes (two digits per uint8
plus occupancy maps, DESIGN.md §14) and additionally report
``plane_reduction_vs_v3``: per-device plane bytes against the v3 layout
(dense int4 at its true int8 wire width), asserted >= 1.8x.

Run under an emulated mesh for the scaling curve (what CI does):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.bench_serve_sharded

Output: ``serve_sharded,...`` CSV lines + ``bench_serve_sharded.json``
(schema documented in benchmarks/README.md).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np


def plane_bytes(artifact, n_dev: int, *, layout: str = "v4"):
    """(per_device_sharded, replicated) bytes for one column-shard count.

    Walks the packed tree with the same rule ``DeployArtifact.shard``
    uses: arrays in a CIM node whose last axis is the node's column count
    shard when the columns divide n_dev; ragged nodes — and everything
    without a full column axis — replicate (shard() keeps ragged layers
    resident everywhere; the kernel pads-and-shards them per call).

    Bytes are what actually crosses the wire, not the nominal element
    width: dense int4 planes stream as int8 (the kernel wrappers upcast
    before the pallas_call — charging them 4 bits, as this bench did
    before layout v4, undercounted 2x), nibble-packed uint8 planes are
    counted as stored. ``layout='v3'`` re-prices a v4 tree at the old
    layout — nibble planes back at one byte per *logical* digit, no
    occupancy maps — to measure what v4 saves on the same model."""
    import jax.numpy as jnp
    sharded = 0
    replicated = 0

    def nbytes(k, a):
        if layout == "v3":
            if k.endswith("_occ"):            # v3 had no skip maps
                return 0
            if k.endswith("_digits") and a.dtype == jnp.uint8:
                return int(a.size) * 2        # dense int4 @ int8 wire
        bits = 8 if a.dtype == jnp.int4 else a.dtype.itemsize * 8
        return int(a.size * bits) // 8

    def walk(node):
        nonlocal sharded, replicated
        if isinstance(node, dict):
            if "w_digits" in node:
                n = int(node["w_digits"].shape[-1])
                for k, v in node.items():
                    if (getattr(v, "ndim", 0) >= 1 and v.shape[-1] == n
                            and n % n_dev == 0):
                        sharded += nbytes(k, v) // n_dev
                    else:
                        replicated += (nbytes(k, v)
                                       if hasattr(v, "size") else 0)
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
        else:
            replicated += nbytes("", node) if hasattr(node, "size") else 0
    walk(artifact.params)
    return sharded, replicated


def run(csv=None, *, batch=2, prompt_len=8, new_tokens=16, out_json=None):
    from repro.api import CIMConfig, model_artifact
    from repro.configs.registry import get_config
    from repro.models.registry import get_model
    from repro.nn import init_params
    from repro.nn.module import session_mesh
    from repro.serve.engine import engine_from_artifact

    cfg = None
    n_avail = len(jax.devices())
    counts = [d for d in (1, 2, 4, 8, 16) if d <= n_avail]

    points = []
    for pack in ("int8", "int4"):
        cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4,
                        cell_bits=2, act_bits=8, psum_bits=6, array_rows=128,
                        array_cols=128, use_kernel=False, pack_dtype=pack)
        if cfg is None:
            cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
            model = get_model(cfg)
            params = init_params(model.specs(cfg.replace(cim=cim)),
                                 jax.random.PRNGKey(0))
            prompts = np.random.RandomState(0).randint(
                0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
        artifact = model_artifact(params, cim, meta={"arch": "qwen3-0.6b"})

        base = None
        bytes_1dev = None
        for d in counts:
            mesh = None if d == 1 else jax.make_mesh((d,), ("model",))
            with session_mesh(mesh):  # scope: next d must not see this mesh
                eng = engine_from_artifact(artifact, cfg.replace(cim=cim),
                                           mesh=mesh, batch_size=batch,
                                           max_len=256)
                eng.generate_batch(prompts, 2)      # warm the jit caches
                t0 = time.time()
                out = eng.generate_batch(prompts, new_tokens)
                dt = time.time() - t0
            if base is None:
                base = np.asarray(out)
            assert np.array_equal(base, np.asarray(out)), \
                f"sharded serving diverged at {d} devices (pack={pack})"
            tps = out.shape[0] * out.shape[1] / dt
            shard_b, rep_b = plane_bytes(artifact, d)
            if bytes_1dev is None:
                bytes_1dev = shard_b + rep_b
            # §7 roofline: decode is weight-HBM-bound, so modeled
            # tokens/sec scales inversely with per-device bytes per step
            speedup = round(bytes_1dev / (shard_b + rep_b), 3)
            point = {"devices": d, "pack_dtype": pack,
                     "tokens_per_sec": round(tps, 2),
                     "per_device_plane_bytes": shard_b,
                     "replicated_bytes": rep_b,
                     "modeled_decode_speedup": speedup}
            if pack == "int4":
                # what the v3 layout streamed for the same shard (dense
                # int4 at int8 wire width, no occupancy maps)
                v3_b, _ = plane_bytes(artifact, d, layout="v3")
                point["v3_plane_bytes"] = v3_b
                point["plane_reduction_vs_v3"] = round(v3_b / shard_b, 3)
                assert v3_b / shard_b >= 1.8, \
                    "nibble packing must cut per-device int4 plane " \
                    "bytes >= 1.8x vs the v3 layout"
            points.append(point)
            line = (f"serve_sharded,{pack},{d},{tps:.2f},{shard_b},{rep_b},"
                    f"{speedup}")
            print(line)
            if csv is not None:
                csv.append(line)

    doc = {"schema": "bench_serve_sharded/v2", "arch": "qwen3-0.6b-reduced",
           "batch": batch, "prompt_len": prompt_len,
           "new_tokens": new_tokens,
           # only meaningful when more than one mesh size was compared
           "bit_exact_across_meshes": len(counts) > 1,
           "points": points}
    if out_json is not None:
        # opt-in (module entry point / CI sharded job): tokens_per_sec is
        # wall-clock, so the smoke tier must not churn the checked-in
        # sample on every run
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"[bench_serve_sharded] wrote {out_json} "
              f"({len(points)} mesh points, {n_avail} devices visible)")
    return doc


if __name__ == "__main__":
    run(out_json="bench_serve_sharded.json")
