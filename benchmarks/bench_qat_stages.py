"""Paper Fig. 9: one-stage QAT (granularity aligned, ours) vs two-stage QAT
(Saxena'23 style: stage 1 trains with full-precision partial sums, stage 2
adds psum quantization). Reports accuracy and wall-clock training cost."""
from __future__ import annotations

from repro.core.granularity import Granularity as G

from .common import _data, make_cim, train_qat


def run(steps=150, seed=0, csv=None):
    data = _data(seed)
    rows = []

    # (i) ours: column/column one-stage
    r = train_qat(make_cim(G.COLUMN, G.COLUMN), steps=steps, seed=seed,
                  data=data)
    rows.append(("one-stage col/col (ours)", r["acc"], r["train_time"]))

    # (ii) ours' granularity, two-stage (ablation): stage1 w/o psq
    s1 = train_qat(make_cim(G.COLUMN, G.COLUMN), steps=steps // 2, seed=seed,
                   freeze_psum=True, data=data)
    s2 = train_qat(make_cim(G.COLUMN, G.COLUMN), steps=steps // 2, seed=seed,
                   params=s1["params"], state=s1["state"], data=data)
    rows.append(("two-stage col/col", s2["acc"],
                 s1["train_time"] + s2["train_time"]))

    # (iii) Saxena'23: layer weight / column psum, two-stage
    s1 = train_qat(make_cim(G.LAYER, G.COLUMN), steps=steps // 2, seed=seed,
                   freeze_psum=True, data=data)
    s2 = train_qat(make_cim(G.LAYER, G.COLUMN), steps=steps // 2, seed=seed,
                   params=s1["params"], state=s1["state"], data=data)
    rows.append(("two-stage layer/col (Saxena'23)", s2["acc"],
                 s1["train_time"] + s2["train_time"]))

    # (iv) layer/column one-stage
    r = train_qat(make_cim(G.LAYER, G.COLUMN), steps=steps, seed=seed,
                  data=data)
    rows.append(("one-stage layer/col", r["acc"], r["train_time"]))

    print("\n== Fig.9: QAT schemes — accuracy vs training cost ==")
    for name, acc, tt in rows:
        line = f"qat_stages,{name},acc={acc:.4f},train_s={tt:.1f}"
        print(line)
        if csv is not None:
            csv.append(line)
    return rows


if __name__ == "__main__":
    run()
