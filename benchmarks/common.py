"""Shared benchmark harness: scaled-down QAT of ResNet-20 on the synthetic
class-conditional image set (paper Table II settings, reduced for CPU).

Absolute top-1 numbers are not comparable to the paper's CIFAR results (no
CIFAR on this box); every benchmark reports the *relative* quantity the
paper claims: granularity orderings, overhead-iso accuracy, one- vs
two-stage cost, variation robustness curves.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity
from repro.data.pipeline import make_image_dataset, synth_classification_batch
from repro.models.resnet import ResNetConfig, calibrate, forward, init

HW = 16
N_CLASSES = 10
WIDTHS = (8, 16, 32)


def make_cim(gw: Granularity, gp: Granularity, *, psum_quant=True,
             weight_bits=3, cell_bits=1, act_bits=3, psum_bits=4,
             array=128, variation_std=0.0) -> CIMConfig:
    """Paper Table II CIFAR-10 column: 3b act / 3b weight (1b/cell),
    low-bit psums, 128x128 arrays."""
    return CIMConfig(enabled=True, mode="emulate", weight_bits=weight_bits,
                     cell_bits=cell_bits, act_bits=act_bits,
                     psum_bits=psum_bits, array_rows=array, array_cols=array,
                     weight_granularity=gw, psum_granularity=gp,
                     act_signed=False, psum_quant=psum_quant,
                     variation_std=variation_std)


def resnet_cfg(cim: CIMConfig) -> ResNetConfig:
    return ResNetConfig(name="resnet20-bench", depth=20, n_classes=N_CLASSES,
                        widths=WIDTHS, in_hw=HW, cim=cim)


def _data(seed=0, n=1536):
    x, y = make_image_dataset(n_classes=N_CLASSES, hw=HW, n=n, seed=seed)
    n_test = n // 4
    return (x[n_test:], y[n_test:]), (x[:n_test], y[:n_test])


def _loss_fn(params, state, xb, yb, cfg):
    logits, new_state = forward(params, state, xb, cfg, train=True)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1)), new_state


def evaluate(params, state, cfg, x, y, batch=128,
             variation_key: Optional[jax.Array] = None) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i:i + batch])
        vk = None
        if variation_key is not None:
            variation_key, vk = jax.random.split(variation_key)
        logits, _ = forward(params, state, xb, cfg, train=False,
                            variation_key=vk)
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += int((pred == y[i:i + batch]).sum())
    return correct / len(x)


def train_qat(cim: CIMConfig, *, steps=150, batch=64, lr=0.05, seed=0,
              params=None, state=None, freeze_psum: bool = False,
              data=None) -> Dict:
    """One-stage QAT from scratch (paper's scheme) or a stage of a
    two-stage schedule (freeze_psum=True disables psum quantization)."""
    cfg = resnet_cfg(cim.replace(psum_quant=cim.psum_quant and not freeze_psum))
    (xtr, ytr), (xte, yte) = data or _data(seed)
    if params is None:
        params, state = init(jax.random.PRNGKey(seed), cfg)
        if cfg.cim.enabled:
            params = calibrate(params, state,
                               jnp.asarray(xtr[:128]), cfg)

    mom = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    @jax.jit
    def step_fn(params, state, mom, xb, yb, lr_t):
        (loss, new_state), g = jax.value_and_grad(_loss_fn, has_aux=True)(
            params, state, xb, yb, cfg)
        mom = jax.tree.map(lambda m, gg: 0.9 * m + gg.astype(jnp.float32),
                           mom, g)
        params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, mom)
        return params, new_state, mom, loss

    t0 = time.time()
    losses = []
    for it in range(steps):
        xb, yb = synth_classification_batch(xtr, ytr, batch, it, seed)
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * it / steps))
        params, state, mom, loss = step_fn(params, state, mom,
                                           jnp.asarray(xb), jnp.asarray(yb),
                                           lr_t)
        losses.append(float(loss))
    train_time = time.time() - t0
    acc = evaluate(params, state, cfg, xte, yte)
    return {"params": params, "state": state, "acc": acc,
            "train_time": train_time, "losses": losses, "cfg": cfg}
