"""Serving load generator: request latency p50/p99 and tokens/sec under
concurrent streams (DESIGN.md §12).

Packs a reduced LM once, then drives the continuous-batching engine
(``submit``/``step`` — the slot path, not lockstep ``generate_batch``)
with closed bursts of ``concurrency`` requests against ``batch`` slots.
Every number comes out of the engine's own telemetry plane
(``repro.obs``): request latency and queue-wait percentiles from the
registry histograms (exact, numpy-convention interpolation), throughput
from the decode-step span histogram, and the ADC saturation summary from
the armed collector (``every_n``-decimated folding — the same sampled
mode a production deployment would run).

All prompts in a burst share one length, so the engine's
single-slot-prefill synchronization caveat (serve/engine.py ``_admit``)
does not bias the latency distribution: admission happens in waves and
each wave's prefill cost is identical.

This is the repo's headline serving-performance artifact
(``bench_serve_load.json``; schema in benchmarks/README.md). On a CPU
host the absolute tokens/sec is an emulation number — the shape that
matters is the latency/throughput trade as concurrency outruns the slot
count (queue wait comes to dominate p99 while tokens/sec saturates).

  PYTHONPATH=src python -m benchmarks.bench_serve_load

Output: ``serve_load,...`` CSV lines + ``bench_serve_load.json`` (only
from the module entry point — wall-clock numbers must not churn the
checked-in sample on every smoke run).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np


def _burst(eng, reg, *, concurrency, prompt_len, new_tokens, vocab, seed,
           timeline_every=4):
    """Submit ``concurrency`` requests at once and step until drained.
    Returns (wall_seconds, completed, queue-depth timeline)."""
    rng = np.random.RandomState(seed)
    t0 = time.time()
    for _ in range(concurrency):
        eng.submit(rng.randint(0, vocab, size=(prompt_len,)), new_tokens)
    timeline = []
    done, steps = 0, 0
    budget = concurrency * (prompt_len + new_tokens) * 4  # stall guard
    while done < concurrency and steps < budget:
        done += len(eng.step())
        steps += 1
        if steps % timeline_every == 1 or done == concurrency:
            timeline.append({
                "step": steps,
                "queue_depth": len(eng.queue),
                "active_slots": sum(s is not None for s in eng.slots)})
    assert done == concurrency, f"burst stalled: {done}/{concurrency}"
    return time.time() - t0, done, timeline


def run(csv=None, *, concurrency=(8, 32, 128), batch=8, prompt_len=4,
        new_tokens=8, every_n=4, out_json=None):
    from repro.api import CIMConfig, model_artifact
    from repro.configs.registry import get_config
    from repro.models.registry import get_model
    from repro.nn import init_params
    from repro.obs import MetricsRegistry, adc, names
    from repro.serve.engine import engine_from_artifact

    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=128, array_cols=128,
                    use_kernel=False)
    cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    artifact = model_artifact(params, cim, meta={"arch": "qwen3-0.6b"})

    points = []
    for ci, c in enumerate(concurrency):
        reg = MetricsRegistry()
        with adc.sampled(reg, every_n=every_n):
            eng = engine_from_artifact(artifact, cfg, batch_size=batch,
                                       max_len=256, metrics=reg)
            # warm the jit caches (prefill + decode traces), then zero the
            # telemetry so the point measures steady-state serving only
            _burst(eng, reg, concurrency=1, prompt_len=prompt_len,
                   new_tokens=new_tokens, vocab=cfg.vocab, seed=99)
            reg.reset()
            adc.reset()
            eng.retired = 0
            wall, done, timeline = _burst(
                eng, reg, concurrency=c, prompt_len=prompt_len,
                new_tokens=new_tokens, vocab=cfg.vocab, seed=ci)
            adc.sync()
            sat = adc.summary()
            m = eng.metrics()
        lat = reg.histogram(names.REQUEST_LATENCY_SECONDS)
        qw = reg.histogram(names.QUEUE_WAIT_SECONDS)
        tps = done * new_tokens / wall
        n_dev = m["throughput"]["devices"]
        point = {
            "concurrency": c,
            "completed": done,
            "p50_latency_s": round(lat.percentile(50), 4),
            "p99_latency_s": round(lat.percentile(99), 4),
            "p50_queue_wait_s": round(qw.percentile(50), 4),
            "p99_queue_wait_s": round(qw.percentile(99), 4),
            "tokens_per_sec": round(tps, 2),
            "tokens_per_sec_per_device": round(tps / n_dev, 2),
            "wall_s": round(wall, 2),
            "queue_depth_timeline": timeline,
            "saturation": {
                "conversions": sat["conversions"],
                "saturated": sat["saturated"],
                "clip_rate": round(sat["clip_rate"], 6),
                "worst_col_rate": round(sat["worst_col_rate"], 6),
                "every_n": sat["every_n"],
            },
        }
        points.append(point)
        line = (f"serve_load,{c},{point['p50_latency_s']},"
                f"{point['p99_latency_s']},{point['tokens_per_sec']},"
                f"{point['saturation']['clip_rate']}")
        print(line)
        if csv is not None:
            csv.append(line)

    doc = {"schema": "bench_serve_load/v1", "arch": "qwen3-0.6b-reduced",
           "slots": batch, "prompt_len": prompt_len,
           "new_tokens": new_tokens, "adc_every_n": every_n,
           "points": points}
    if out_json is not None:
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"[bench_serve_load] wrote {out_json} "
              f"({len(points)} concurrency points)")
    return doc


if __name__ == "__main__":
    run(out_json="bench_serve_load.json")
