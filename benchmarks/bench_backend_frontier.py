"""Hardware-style backend frontier -> bench_backend_frontier.json.

One chart, three hardware styles (DESIGN.md §13), three axes per point:

  cost        analytic bench-ResNet conv sweep (``bench_hw_cost.
              layer_cost`` at the matching style) — energy / latency /
              area / conversions
  accuracy    relative output error vs the fp32 matmul of a calibrated
              CIM linear layer on a fixed-key workload, served through
              the style's own packed forward
  robustness  Monte-Carlo mean relative error under log-normal cell
              noise (``repro.eval.robustness.monte_carlo_linear_error``
              — the same harness the variation bench uses), per sigma

Points: ``deploy`` and ``binary`` swept over PSUM_BITS (the ADC
resolution trade the paper's column-wise s_p exists to win), plus one
``adc_free`` point (no ADC — psum_bits is inert for accuracy; its cost
is the digital accumulator at full psum width). The JSON artifact is
checked in at the repo root: fixed-seed, single-host CPU arithmetic,
regenerate with

  PYTHONPATH=src python -m benchmarks.bench_backend_frontier [--out PATH]

The ``--smoke`` tier (and ``run.py --smoke``) runs a tiny workload and
never writes JSON.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.granularity import Granularity as G

from .bench_hw_cost import PSUM_BITS, _bench_conv_layers, layer_cost
from .common import make_cim

SIGMAS = (0.05, 0.1, 0.2)
MC_SAMPLES = 8

# the linear accuracy/robustness workload (fixed keys => deterministic)
K, N, BATCH = 192, 96, 64


def _workload(k=K, n=N, m=BATCH):
    # non-negative activations: the Table II configs are post-ReLU
    # (act_signed=False), so a zero-mean workload would just measure clip
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.5
    return jax.nn.relu(x)


def _style_cfg(style: str, psum_bits: int, *, k=K, n=N):
    # paper Table II bit widths (3b act / 3b weight, 1b cells); the
    # binary backend overrides the PLANE geometry itself via plane_bits
    return make_cim(G.COLUMN, G.COLUMN, psum_bits=psum_bits).replace(
        mode=style, use_kernel=False)


def _point(style: str, psum_bits: int, x, *, n_samples=MC_SAMPLES,
           sigmas=SIGMAS, k=K, n=N):
    import repro.api as api
    cfg = _style_cfg(style, psum_bits, k=k, n=n)
    params = api.init_linear(jax.random.PRNGKey(1), k, n, cfg)
    params = api.calibrate_linear(x, params, cfg)
    packed = api.pack_linear(params, cfg)
    y = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
    y_fp = x @ params["w"].astype(jnp.float32)
    rel_err = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))

    from repro.eval.robustness import monte_carlo_linear_error
    mc = monte_carlo_linear_error(packed, cfg, x, key=jax.random.PRNGKey(2),
                                  sigmas=sigmas, n_samples=n_samples)
    robust = {f"sigma={s}": float(np.mean(mc[i]))
              for i, s in enumerate(sigmas)}

    layers = [layer_cost(*spec, cfg, style=style)
              for spec in _bench_conv_layers()]
    cost = {kk: sum(L[kk] for L in layers)
            for kk in ("n_arrays", "cells_used", "conversions", "energy_pj",
                       "e_adc_pj", "latency_ns", "area_um2")}
    return {
        "style": style, "psum_bits": psum_bits,
        "accuracy": {"rel_err_fp32": rel_err},
        "robustness": robust,
        "cost": cost,
    }


def run(csv=None, out=None, *, smoke=False):
    """Sweep the three styles onto one frontier; smoke = tiny tier."""
    k, n = (64, 32) if smoke else (K, N)
    x = _workload(k=k, n=n, m=8 if smoke else BATCH)
    sigmas = (0.1,) if smoke else SIGMAS
    n_samples = 2 if smoke else MC_SAMPLES
    sweep_bits = (4,) if smoke else PSUM_BITS

    points = []
    for style in ("deploy", "binary"):
        for pb in sweep_bits:
            points.append(_point(style, pb, x, n_samples=n_samples,
                                 sigmas=sigmas, k=k, n=n))
    # adc_free has no ADC: one point, psum_bits inert for accuracy (the
    # cost model charges the full-width digital accumulator instead)
    points.append(_point("adc_free", sweep_bits[-1], x,
                         n_samples=n_samples, sigmas=sigmas, k=k, n=n))

    report = {}
    for pt in points:
        key = f"style={pt['style']},psum_bits={pt['psum_bits']}"
        report[key] = pt
        sig = f"sigma={sigmas[len(sigmas) // 2]}"
        line = (f"backend_frontier,{key},"
                f"rel_err={pt['accuracy']['rel_err_fp32']:.4f},"
                f"mc_{sig}={pt['robustness'][sig]:.4f},"
                f"energy_pj={pt['cost']['energy_pj']:.1f},"
                f"latency_ns={pt['cost']['latency_ns']:.0f},"
                f"area_um2={pt['cost']['area_um2']:.0f}")
        print(line)
        if csv is not None:
            csv.append(line)
    if out:
        head = {
            "workload": {"kind": "linear", "k": k, "n": n,
                         "batch": int(x.shape[0]), "seed": 0},
            "mc": {"sigmas": list(sigmas), "n_samples": n_samples},
            "cost_model": "bench_hw_cost.layer_cost over the bench "
                          "ResNet-20 conv layers",
        }
        with open(out, "w") as f:
            json.dump({"meta": head, "points": report}, f, indent=1)
        print(f"wrote {out}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_backend_frontier.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tier, never writes JSON")
    args = ap.parse_args(argv)
    run(out=None if args.smoke else args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
