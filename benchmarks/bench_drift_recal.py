"""Self-healing serving benchmark: accuracy over request count under a
conductance-drift schedule, with and without in-service recalibration
(DESIGN.md §11 — the robustness headline next to ``bench_hw_cost.json``).

One QAT ResNet-20 is packed once; the same packed planes then serve a
simulated deployment lifetime. At each request count ``t`` on the grid
the chip is one ``core.variation.drift_tree`` realization of the
pristine planes under the default drift schedule (column-gain dominant —
the component the paper's per-column scales can absorb — plus smaller
per-cell and read components). Two serving policies are compared on the
identical chip realizations (common random numbers):

* **no recal** — the artifact as shipped, drifting unattended;
* **self-healing** — a ``serve.health.DriftMonitor`` watches the logit
  statistics of every evaluation batch; when the drift score crosses the
  soft threshold, ``eval.recalibrate.fit_scale_delta`` re-fits the
  per-column scales against the drift at that ``t`` (probe codes, digit
  planes untouched) and the fitted ``ScaleDelta`` serves from then on.

The JSON acceptance block asserts the PR's claim: recalibrated accuracy
strictly dominates the unattended curve beyond the detection point, and
the final recalibrated point sits within 1% of clean deploy accuracy.

  PYTHONPATH=src python -m benchmarks.bench_drift_recal [--smoke]

``--smoke`` runs a minutes-scale tier (tiny QAT, short grid) and — like
the other benches — never overwrites the checked-in
``bench_drift_recal.json``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import _data, evaluate, make_cim, resnet_cfg, train_qat
from repro.core.granularity import Granularity as G
from repro.core.variation import DriftSchedule, drift_tree
from repro.eval.recalibrate import apply_scale_delta_params, fit_scale_delta
from repro.models.resnet import forward
from repro.serve.health import DriftMonitor, HealthConfig, logit_stats

# sized so sigma_col(T) = 0.6 at the end of the default grid: strong
# enough to crater unattended accuracy, coherent enough that per-column
# scale refits recover it
DEFAULT_SCHEDULE = dict(read_sigma=0.01, read_rate=0.0,
                        cell_rate=4e-5, col_rate=6e-4)
DEFAULT_TS = (0, 50, 100, 200, 300, 450, 600, 800, 1000)


def _acc_and_stats(logits_fn, params, xb_list, yb_list, t):
    """Accuracy over the eval batches at request count ``t``, plus the
    logit statistics of the first batch (what the monitor ingests)."""
    correct, n, stats = 0, 0, None
    for xb, yb in zip(xb_list, yb_list):
        lg = logits_fn(params, xb, jnp.int32(t))
        if stats is None:
            stats = logit_stats(lg)
        correct += int((np.asarray(jnp.argmax(lg, -1)) == yb).sum())
        n += len(yb)
    return correct / n, stats


def run(csv=None, *, steps=150, smoke=False, out_json=None, seed=0,
        schedule=None, ts=None, probes=64):
    from repro.api import pack_model

    cim = make_cim(G.COLUMN, G.COLUMN)
    if smoke:
        steps, ts = min(steps, 10), ts or (0, 200, 600)
    ts = tuple(ts or DEFAULT_TS)
    sched = DriftSchedule(**(schedule or DEFAULT_SCHEDULE))

    # two-stage QAT (the bench_qat_stages schedule): psum quantization
    # frozen for the first half, enabled for the second — the one-stage
    # run does not converge at this scaled-down CPU budget
    data = _data(seed)
    s1 = train_qat(cim, steps=max(1, steps // 2), seed=seed,
                   freeze_psum=True, data=data)
    res = train_qat(cim, steps=max(1, steps - steps // 2), seed=seed,
                    params=s1["params"], state=s1["state"], data=data)
    dcfg = resnet_cfg(cim.replace(mode="deploy"))
    pristine = pack_model(res["params"], cim)
    state = res["state"]

    (_, _), (xte, yte) = data
    if smoke:
        xte, yte = xte[:128], yte[:128]
    batch = 128
    xb_list = [jnp.asarray(xte[i:i + batch]) for i in range(0, len(xte), batch)]
    yb_list = [np.asarray(yte[i:i + batch]) for i in range(0, len(yte), batch)]

    drift_key = jax.random.fold_in(jax.random.PRNGKey(seed), 0xD81F)

    @jax.jit
    def logits_at(params, xb, t):
        # one chip realization at request count t; t is traced, so the
        # whole grid reuses one compile per param-tree structure
        drifted = drift_tree(params, drift_key, sched.at(t))
        lg, _ = forward(drifted, state, xb, dcfg, train=False)
        return lg

    acc_clean = evaluate(pristine, state, dcfg, xte, yte)

    # -- policy 1: unattended -------------------------------------------------
    no_recal = {t: _acc_and_stats(logits_at, pristine, xb_list, yb_list, t)[0]
                for t in ts}

    # -- policy 2: monitored + self-healing ----------------------------------
    monitor = DriftMonitor(HealthConfig(warmup=6, soft_threshold=4.0,
                                        hard_threshold=12.0))
    for xb in xb_list[:max(6, len(xb_list))] * 3:   # warmup on clean logits
        if monitor.warmed_up:
            break
        lg, _ = forward(pristine, state, xb, dcfg, train=False)
        monitor.observe(logit_stats(lg))

    serving = pristine
    detection_t = None
    points = []
    for t in ts:
        acc, stats = _acc_and_stats(logits_at, serving, xb_list, yb_list, t)
        monitor.observe(stats)
        if monitor.drifted:
            # detected: re-fit the column scales against the drift at t
            # (deltas are absolute — always fitted from the pristine tree)
            observed = drift_tree(pristine, drift_key, sched.at(jnp.int32(t)))
            delta = fit_scale_delta(
                pristine, observed, probes=probes,
                key=jax.random.fold_in(jax.random.PRNGKey(seed), t),
                meta={"t": int(t)})
            serving = apply_scale_delta_params(pristine, delta)
            monitor.note_recalibration()
            if detection_t is None:
                detection_t = t
            acc, _ = _acc_and_stats(logits_at, serving, xb_list, yb_list, t)
        points.append({"t": int(t), "acc_no_recal": round(no_recal[t], 4),
                       "acc_recal": round(acc, 4),
                       "drift_score": round(monitor.score, 3),
                       "recalibrations": monitor.recalibrations})
        line = (f"drift_recal,{t},{no_recal[t]:.4f},{acc:.4f},"
                f"{monitor.recalibrations}")
        print(line)
        if csv is not None:
            csv.append(line)

    final = points[-1]
    beyond = [p for p in points if detection_t is not None
              and p["t"] > detection_t]
    acceptance = {
        "detection_t": detection_t,
        "recal_dominates_beyond_detection": bool(
            beyond and all(p["acc_recal"] > p["acc_no_recal"]
                           for p in beyond)),
        "final_recal_within_1pct_of_clean": bool(
            final["acc_recal"] >= acc_clean - 0.01),
    }
    doc = {"schema": "bench_drift_recal/v1", "arch": "resnet20-bench",
           "qat_steps": steps, "probes": probes,
           "schedule": dict(schedule or DEFAULT_SCHEDULE),
           "acc_clean": round(acc_clean, 4),
           "acceptance": acceptance, "points": points}
    print(f"[bench_drift_recal] clean={acc_clean:.4f} "
          f"detection_t={detection_t} acceptance={acceptance}")
    if out_json is not None and not smoke:
        # the checked-in sample comes from the full tier only; the smoke
        # tier (CI) must never churn it
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"[bench_drift_recal] wrote {out_json} ({len(points)} points)")
    return doc


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minutes-scale tier; never writes the JSON")
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    run(steps=args.steps, smoke=args.smoke,
        out_json=None if args.smoke else "bench_drift_recal.json")
