"""Benchmark driver: one function per paper table/figure + the framework's
own kernel/LM benches. Prints ``name,...`` CSV lines (tee'd by the final
deliverable run).

  PYTHONPATH=src python -m benchmarks.run [--steps N] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="QAT steps per scheme (paper uses 200 epochs; this"
                         " is the scaled-down CPU setting)")
    ap.add_argument("--fast", action="store_true",
                    help="minimal QAT steps")
    ap.add_argument("--smoke", action="store_true",
                    help="analytic + kernel benches only (CI smoke; skips "
                         "the QAT/LM training benches, which take tens of "
                         "minutes on CPU)")
    args = ap.parse_args(argv)
    steps = 30 if args.fast else args.steps

    from . import (bench_backend_frontier, bench_conv_kernel,
                   bench_dequant_overhead, bench_drift_recal,
                   bench_granularity, bench_hw_cost, bench_kernel,
                   bench_lm_cim, bench_psum_range, bench_qat_stages,
                   bench_serve_load, bench_serve_sharded, bench_variation)

    csv = []
    t0 = time.time()
    bench_dequant_overhead.run(csv=csv)            # Fig. 8 (analytic)
    bench_psum_range.run(csv=csv)                  # Fig. 6
    bench_hw_cost.run(csv=csv)                     # analytic HW cost model
    bench_kernel.run(csv=csv)                      # kernel microbench
    bench_conv_kernel.run(csv=csv)                 # fused conv deploy bench
    bench_serve_sharded.run(csv=csv)               # column-parallel serving
    # load generator at tiny scale — the checked-in JSON artifact comes
    # from the module entry point, never from this tier
    bench_serve_load.run(csv=csv, concurrency=(2, 4, 8), batch=2,
                         prompt_len=2, new_tokens=2)
    # hardware-style frontier at tiny scale — the checked-in JSON comes
    # from the module entry point, never from this tier (no JSON churn)
    bench_backend_frontier.run(csv=csv, smoke=True)
    if not args.smoke:
        bench_granularity.run(steps=steps, csv=csv)   # Fig. 7 / Table III
        bench_qat_stages.run(steps=steps, csv=csv)    # Fig. 9
        bench_variation.run(steps=steps, csv=csv)     # Fig. 10 (MC deploy)
        bench_drift_recal.run(steps=steps, csv=csv)   # self-healing serving
        bench_lm_cim.run(steps=max(20, steps // 3), csv=csv)  # LM (beyond paper)

    print(f"\n== CSV summary ({time.time() - t0:.0f}s total) ==")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
