"""Analytic per-layer hardware cost model -> bench_hw_cost.json.

Walks every CIM conv of the benchmark ResNet (the paper's Table II
geometry at this repo's scaled-down shapes) and charges energy / latency
/ area from the array tiling — no training, no RNG, fully deterministic,
which is why the JSON artifact is checked in at the repo root (see
benchmarks/README.md for the schema and the regeneration command).

Cost model (constants below; pJ / ns / um^2):

  MAC        E_MAC per used cell per output position
  DAC        E_DAC_BIT per input element bit (inputs are driven once per
             output position, shared across splits/columns of an array)
  ADC        E_ADC(b) = ADC_E_LIN*b + ADC_E_EXP*4^b per conversion, the
             standard SAR-ADC energy scaling; conversions = one per
             (position, split, k_tile, output column)
  shift+add  E_SA per conversion entering the shift-and-add tree
  dequant    E_DQ per (position, split, k_tile): the fused column scale
             2^{cs}*s_w*s_a is one multiply per partial-sum word
  latency    (LAT_PER_BIT*psum_bits + LAT_BASE) ns per output position
             (ADC readout serializes the column mux; arrays in parallel)
  area       A_CELL per cell + A_ADC(b) = ADC_A_LIN*b + ADC_A_EXP*2^b
             per column, times 128 columns, times n_arrays

  PYTHONPATH=src python -m benchmarks.bench_hw_cost [--out PATH]
"""
from __future__ import annotations

import argparse
import json

from repro.core.granularity import Granularity as G, conv_tiling
from repro.models import resnet

from .common import HW, WIDTHS, make_cim, resnet_cfg

# energy (pJ)
E_MAC = 0.25e-3            # per used cell per output position
E_DAC_BIT = 1.7e-3         # per input element bit
ADC_E_LIN = 2.0e-3         # * psum_bits per conversion
ADC_E_EXP = 0.1e-3         # * 4^psum_bits per conversion
E_SA = 0.3e-3              # per conversion
E_DQ = 25.2e-3             # per (position, split, k_tile)
# latency (ns per output position)
LAT_PER_BIT = 4.0
LAT_BASE = 3.0
# area (um^2)
A_CELL = 0.05              # per cell
ADC_A_LIN = 3.75           # * psum_bits per column
ADC_A_EXP = 0.25           # * 2^psum_bits per column

# adc_free style (DESIGN.md §13): the per-column SAR ADC is replaced by a
# digital accumulator at the FULL psum width act_bits + cell_bits +
# ceil(log2(rows)) — energy/area linear in that width (an adder tree has
# no 4^b conversion wall), latency a fixed digital-pipeline beat.
E_ACC_BIT = 0.05e-3        # per accumulation per accumulator bit
A_ACC_BIT = 0.6            # per column per accumulator bit
LAT_ACC = 2.0              # ns per output position (pipelined adder tree)

PSUM_BITS = (2, 4, 6, 8)


def _bench_conv_layers():
    """(name, kh, c_in, c_out, m_out) for every CIM conv of the bench
    ResNet-20 (stem/fc stay full precision), batch=1. Layer identity
    (names, strides, proj placement) comes from
    ``resnet.conv_layer_names`` — the single source ``forward`` and the
    robustness harness share — only channels/spatial extents are derived
    here."""
    cfg = resnet_cfg(make_cim(G.COLUMN, G.COLUMN))
    layers = []
    for name, stride in resnet.conv_layer_names(cfg):
        blk, conv = name.split(".")
        si, bi = int(blk[1]), int(blk[3])
        w = WIDTHS[si]
        prev = WIDTHS[si - 1] if (bi == 0 and si > 0) else w
        kh = 1 if conv == "proj" else 3
        c_in = w if conv == "conv2" else prev
        hw_out = HW >> si          # one stride-2 downsample per stage > 0
        layers.append((name, kh, c_in, w, hw_out * hw_out))
    return layers


def layer_cost(name, kh, c_in, c_out, m_out, cim, style="deploy"):
    """Charge one conv layer under the stretched-kernel tiling.

    ``style`` selects the hardware style (DESIGN.md §13). ``deploy`` (and
    ``ref``, same hardware) is the paper's ADC pipeline. ``adc_free``
    keeps the same tiling but replaces every ADC conversion with a
    digital accumulation at the full psum width (the ``e_adc_pj`` column
    then holds accumulator energy — schema unchanged) and drops the
    per-bit ADC readout serialization from latency. ``binary`` packs S=1
    sign planes (plane_bits=(1,1)), collapsing cells/arrays/conversions
    ~n_split-fold, with the standard ADC still charged."""
    wb, cb = (1, 1) if style == "binary" else (cim.weight_bits,
                                               cim.cell_bits)
    t, cpa = conv_tiling(kh, kh, c_in, c_out, cim.array_rows, cim.array_cols,
                         wb, cb)
    ns, kt, nt = t.n_split, t.k_tiles, t.n_tiles
    n_arrays = kt * nt
    taps = kh * kh
    pb = cim.psum_bits
    cells_used = taps * c_in * c_out * ns
    cells_total = n_arrays * t.array_rows * t.array_cols
    conversions = m_out * ns * kt * c_out

    e_mac = m_out * cells_used * E_MAC
    e_dac = m_out * c_in * taps * cim.act_bits * E_DAC_BIT
    if style == "adc_free":
        acc_bits = cim.act_bits + cb + max(1, (t.array_rows - 1).bit_length())
        e_adc = conversions * E_ACC_BIT * acc_bits
        latency = m_out * (LAT_ACC + LAT_BASE)
        col_area = A_ACC_BIT * acc_bits
    else:
        e_adc = conversions * (ADC_E_LIN * pb + ADC_E_EXP * 4 ** pb)
        latency = m_out * (LAT_PER_BIT * pb + LAT_BASE)
        col_area = ADC_A_LIN * pb + ADC_A_EXP * 2 ** pb
    e_sa = conversions * E_SA
    e_dq = m_out * ns * kt * E_DQ
    energy = e_mac + e_dac + e_adc + e_sa + e_dq
    area = n_arrays * (t.array_rows * t.array_cols * A_CELL
                       + t.array_cols * col_area)
    return {
        "name": name, "kind": "conv",
        "n_split": ns, "k_tiles": kt, "n_tiles": nt, "n_arrays": n_arrays,
        "array_rows": t.array_rows, "array_cols": t.array_cols,
        "cells_used": cells_used, "cells_total": cells_total,
        "utilization": cells_used / cells_total,
        "m_out": m_out, "conversions": conversions,
        "e_mac_pj": e_mac, "e_dac_pj": e_dac, "e_adc_pj": e_adc,
        "e_shift_add_pj": e_sa, "e_dequant_pj": e_dq,
        "latency_ns": latency, "area_um2": area, "energy_pj": energy,
        "adc_energy_fraction": e_adc / energy,
    }


def run(csv=None, out=None):
    """Paper Fig. 6/11 cost axis: ADC (psum) resolution vs energy/area."""
    report = {}
    for pb in PSUM_BITS:
        cim = make_cim(G.COLUMN, G.COLUMN, psum_bits=pb)
        layers = [layer_cost(*spec, cim) for spec in _bench_conv_layers()]
        tot = {k: sum(L[k] for L in layers)
               for k in ("n_arrays", "cells_used", "cells_total",
                         "conversions", "energy_pj", "e_adc_pj",
                         "latency_ns", "area_um2")}
        tot["n_layers"] = len(layers)
        tot["utilization"] = tot["cells_used"] / tot["cells_total"]
        tot["adc_energy_fraction"] = tot["e_adc_pj"] / tot["energy_pj"]
        tot = {"n_layers": tot.pop("n_layers"), **tot}
        report[f"psum_bits={pb}"] = {
            "model": "resnet20-bench", "batch": 1, "psum_bits": pb,
            "weight_bits": cim.weight_bits, "cell_bits": cim.cell_bits,
            "act_bits": cim.act_bits,
            "array": [cim.array_rows, cim.array_cols],
            "layers": layers, "totals": tot,
        }
        line = (f"hw_cost,psum_bits={pb},energy_pj={tot['energy_pj']:.1f},"
                f"adc_frac={tot['adc_energy_fraction']:.3f},"
                f"latency_ns={tot['latency_ns']:.0f},"
                f"area_um2={tot['area_um2']:.0f}")
        print(line)
        if csv is not None:
            csv.append(line)
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {out}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_hw_cost.json")
    args = ap.parse_args(argv)
    run(out=args.out)


if __name__ == "__main__":
    main()
