"""Kernel-level microbenchmark: the fused Pallas cim_matmul vs the naive
(psum-materializing) jnp path. On this CPU box the Pallas kernel runs in
interpret mode, so wall-clock favors the XLA path — the meaningful numbers
are the HBM-traffic model (what the fused kernel avoids) and correctness.
On TPU the kernel's win is structural: the (M, S, kt, N) partial-sum
tensor never leaves VMEM (DESIGN.md §7)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def traffic_model(m, k, n, n_split, array_rows, bytes_act=4, bytes_dig=1):
    """HBM bytes: fused kernel vs materializing every (split, tile) psum."""
    k_tiles = (k + array_rows - 1) // array_rows
    fused = (m * k * bytes_act + n_split * k * n * bytes_dig + m * n * 4
             + 2 * n_split * k_tiles * n * 4)
    naive = fused + 2 * m * n_split * k_tiles * n * 4   # psum write+read
    return fused, naive


def run(csv=None):
    m, k_tiles, rows, n, n_split = 256, 4, 128, 256, 2
    key = jax.random.PRNGKey(0)
    a = jnp.round(jax.random.normal(key, (m, k_tiles, rows)) * 4)
    digits = jax.random.randint(jax.random.PRNGKey(1),
                                (n_split, k_tiles, rows, n), -2, 3
                                ).astype(jnp.int8)
    s_p = jnp.full((n_split, k_tiles, n), 8.0)
    deq = jnp.full((n_split, k_tiles, n), 0.02)

    out_k = None
    results = []
    for use_kernel, name in ((True, "pallas_interpret"), (False, "jnp_ref")):
        fn = jax.jit(lambda a_: ops.cim_matmul(
            a_, digits, s_p, deq, psum_bits=6, use_kernel=use_kernel))
        out = fn(a)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(a)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        results.append((name, us))
        if out_k is None:
            out_k = out
        else:
            np.testing.assert_allclose(np.asarray(out_k), np.asarray(out),
                                       rtol=1e-5, atol=1e-4)

    fused, naive = traffic_model(m, k_tiles * rows, n, n_split, rows)
    print("\n== kernel microbench (CPU; kernel in interpret mode) ==")
    for name, us in results:
        line = f"kernel,{name},us_per_call={us:.0f}"
        print(line)
        if csv is not None:
            csv.append(line)
    line = (f"kernel,hbm_traffic_model,fused_bytes={fused},naive_bytes={naive},"
            f"saving={naive/fused:.2f}x")
    print(line)
    if csv is not None:
        csv.append(line)
    return results


if __name__ == "__main__":
    run()
