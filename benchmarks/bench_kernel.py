"""Kernel-level microbenchmark: the fused Pallas cim_matmul vs the naive
(psum-materializing) jnp path. On this CPU box the Pallas kernel runs in
interpret mode, so wall-clock favors the XLA path — the meaningful numbers
are the HBM-traffic model (what the fused kernel avoids) and correctness.
On TPU the kernel's win is structural: the (M, S, kt, N) partial-sum
tensor never leaves VMEM (DESIGN.md §7)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def dtype_bytes(name: str) -> float:
    """Bytes per element for the deploy storage dtypes."""
    return {"int4": 0.5, "int8": 1.0, "bfloat16": 2.0, "float32": 4.0}[name]


def plane_stream_bytes(pack_dtype: str, rows: int) -> float:
    """Bytes per *logical* digit actually streamed from HBM.

    int4 planes only hit the half-byte width when they nibble-pack two
    digits per uint8 (layout v4: even packed axis, repro.core.nibble);
    dense int4 — odd axes, or pre-v4 artifacts — streams as int8 (the
    kernel wrappers upcast before the pallas_call). Charging unpacked
    int4 at 0.5 B, as this model did before v4, undercounted the wire
    2x."""
    if pack_dtype == "int4":
        return 0.5 if rows % 2 == 0 else 1.0
    return dtype_bytes(pack_dtype)


def traffic_model(m, k, n, n_split, array_rows, *, act_dtype="int8",
                  pack_dtype="int8"):
    """HBM bytes: fused kernel vs materializing every (split, tile) psum.

    Byte widths follow what the deploy path actually *streams*:
    activation codes are int8 (cim_linear casts when the act_bits range
    fits) and digit planes cost ``plane_stream_bytes`` each — nibble-
    packed uint8 for even-row int4 (0.5 B/digit), int8 otherwise — plus
    one occupancy byte per (split, tile, column) for the skip maps. Not
    the 4-byte floats the emulate path moves."""
    bytes_act = dtype_bytes(act_dtype)
    bytes_dig = plane_stream_bytes(pack_dtype, array_rows)
    k_tiles = (k + array_rows - 1) // array_rows
    occ = n_split * k_tiles * n                         # uint8 skip maps
    fused = int(m * k * bytes_act + n_split * k * n * bytes_dig + occ
                + m * n * 4 + 2 * n_split * k_tiles * n * 4)
    naive = fused + 2 * m * n_split * k_tiles * n * 4   # psum write+read
    return fused, naive


def run(csv=None):
    m, k_tiles, rows, n, n_split = 256, 4, 128, 256, 2
    key = jax.random.PRNGKey(0)
    a = jnp.round(jax.random.normal(key, (m, k_tiles, rows)) * 4)
    digits = jax.random.randint(jax.random.PRNGKey(1),
                                (n_split, k_tiles, rows, n), -2, 3
                                ).astype(jnp.int8)
    s_p = jnp.full((n_split, k_tiles, n), 8.0)
    deq = jnp.full((n_split, k_tiles, n), 0.02)

    out_k = None
    results = []
    for use_kernel, name in ((True, "pallas_interpret"), (False, "jnp_ref")):
        fn = jax.jit(lambda a_: ops.cim_matmul(
            a_, digits, s_p, deq, psum_bits=6, use_kernel=use_kernel))
        out = fn(a)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(a)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        results.append((name, us))
        if out_k is None:
            out_k = out
        else:
            np.testing.assert_allclose(np.asarray(out_k), np.asarray(out),
                                       rtol=1e-5, atol=1e-4)

    print("\n== kernel microbench (CPU; kernel in interpret mode) ==")
    for name, us in results:
        line = f"kernel,{name},us_per_call={us:.0f}"
        print(line)
        if csv is not None:
            csv.append(line)
    for pack in ("int8", "int4"):
        fused, naive = traffic_model(m, k_tiles * rows, n, n_split, rows,
                                     pack_dtype=pack)
        line = (f"kernel,hbm_traffic_model,pack={pack},"
                f"plane_B_per_digit={plane_stream_bytes(pack, rows)},"
                f"fused_bytes={fused},"
                f"naive_bytes={naive},saving={naive/fused:.2f}x")
        print(line)
        if csv is not None:
            csv.append(line)
    return results


if __name__ == "__main__":
    run()
