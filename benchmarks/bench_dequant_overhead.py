"""Paper Fig. 8: accuracy vs per-layer dequantization overhead (scale
multiplications). Reproduces the key claim: at ISO overhead, finer WEIGHT
granularity wins — column/column costs exactly what layer/column costs."""
from __future__ import annotations

from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity as G, conv_tiling


def layer_overhead(gw: G, gp: G, kh=3, kw=3, c_in=32, c_out=32,
                   array=128, wb=3, cb=1) -> int:
    t, _ = conv_tiling(kh, kw, c_in, c_out, array, array, wb, cb)
    return t.dequant_muls(gw, gp)


def run(accuracies=None, csv=None):
    combos = [
        ("layer/layer", G.LAYER, G.LAYER),
        ("layer/array", G.LAYER, G.ARRAY),
        ("array/array", G.ARRAY, G.ARRAY),
        ("layer/column", G.LAYER, G.COLUMN),
        ("array/column", G.ARRAY, G.COLUMN),
        ("column/column (ours)", G.COLUMN, G.COLUMN),
    ]
    print("\n== Fig.8: dequant overhead (muls per conv layer, 3x3x32x32) ==")
    rows = []
    for name, gw, gp in combos:
        o = layer_overhead(gw, gp)
        line = f"dequant_overhead,{name},muls={o}"
        print(line)
        rows.append((name, o))
        if csv is not None:
            csv.append(line)
    o = dict(rows)
    assert o["column/column (ours)"] == o["layer/column"], \
        "paper's zero-extra-overhead claim violated"
    assert o["layer/layer"] < o["array/array"] < o["column/column (ours)"]
    return rows


if __name__ == "__main__":
    run()
