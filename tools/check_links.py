#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links (CI docs job).

Scans the repo's markdown files for [text](target) links and verifies
that every non-URL target exists relative to the file (fragments are
stripped; bare-fragment links are ignored). Exits non-zero listing every
broken link, so README/DESIGN can't rot silently.

  python tools/check_links.py [file.md ...]   # default: all tracked *.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REPO = Path(__file__).resolve().parent.parent


def md_files() -> list[Path]:
    return sorted(p for p in REPO.rglob("*.md")
                  if not any(part.startswith(".") or part == "node_modules"
                             for part in p.relative_to(REPO).parts))


def broken_links(path: Path) -> list[str]:
    out = []
    text = path.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                out.append(f"{path.relative_to(REPO)}:{lineno}: "
                           f"broken link -> {target}")
    return out


def main(argv: list[str]) -> int:
    files = [Path(a).resolve() for a in argv] or md_files()
    problems = []
    for f in files:
        problems.extend(broken_links(f))
    for p in problems:
        print(p)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if problems else 'ok'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
