#!/usr/bin/env python3
"""Metric-name documentation lint (CI docs job).

DESIGN.md §12 documents the telemetry plane's canonical metric names in
a table; the single source of truth for those names is
``src/repro/obs/names.py``. This lint holds the two together, both
ways, statically (ast — no jax import needed):

* every ``serve.*`` / ``cim.*`` metric name appearing in DESIGN.md §12
  must be the value of a constant in ``repro/obs/names.py``;
* every constant in ``names.py`` must appear in the §12 table.

  python tools/check_metrics.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
NAMES_PY = REPO / "src" / "repro" / "obs" / "names.py"
DESIGN = REPO / "DESIGN.md"

#: backticked dotted names in the §12 table rows, e.g. `serve.queue.depth`
NAME_RE = re.compile(r"`((?:serve|cim)\.[a-z0-9_.]+)`")


def declared_names() -> set[str]:
    tree = ast.parse(NAMES_PY.read_text(encoding="utf-8"),
                     filename=str(NAMES_PY))
    names = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            names.add(node.value.value)
    if not names:
        raise SystemExit(f"{NAMES_PY}: no string constants found")
    return names


def documented_names() -> set[str]:
    text = DESIGN.read_text(encoding="utf-8")
    marker = "§12"
    at = text.find(f"## {marker}")
    if at < 0:
        raise SystemExit(f"{DESIGN}: no §12 section found")
    return set(NAME_RE.findall(text[at:]))


def main() -> int:
    declared = declared_names()
    documented = documented_names()
    undeclared = sorted(documented - declared)
    undocumented = sorted(declared - documented)
    if undeclared:
        print("DESIGN.md §12 documents metric names that do not exist in "
              "src/repro/obs/names.py:")
        for n in undeclared:
            print(f"  {n}")
    if undocumented:
        print("src/repro/obs/names.py declares metric names missing from "
              "the DESIGN.md §12 table:")
        for n in undocumented:
            print(f"  {n}")
    if undeclared or undocumented:
        return 1
    print(f"ok: {len(declared)} metric names consistent between "
          "DESIGN.md §12 and repro/obs/names.py")
    return 0


if __name__ == "__main__":
    sys.exit(main())
