#!/usr/bin/env python3
"""Public-surface snapshot lint (CI docs job).

Parses ``__all__`` out of the public packages' ``__init__.py`` files
*statically* (ast — no jax import needed) and compares against the
checked-in snapshot ``tools/api_surface.txt``. CI fails when the public
surface drifts without the snapshot being updated in the same change —
accidental exports and silent removals both show up in review.

  python tools/check_api.py            # verify (CI)
  python tools/check_api.py --update   # rewrite the snapshot
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SNAPSHOT = REPO / "tools" / "api_surface.txt"

# public packages whose __all__ is contract; extend as surfaces stabilize
MODULES = (
    "repro.api",
    "repro.backends",
    "repro.core",
    "repro.checkpoint",
    "repro.obs",
    "repro.serve",
)


def module_all(dotted: str) -> list[str]:
    path = REPO / "src" / Path(*dotted.split(".")) / "__init__.py"
    tree = ast.parse(path.read_text(encoding="utf-8"), filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
            if "__all__" in targets:
                value = ast.literal_eval(node.value)
                return sorted(str(v) for v in value)
    raise SystemExit(f"{path}: no literal __all__ found")


def current_surface() -> list[str]:
    lines = []
    for mod in MODULES:
        lines.extend(f"{mod}:{name}" for name in module_all(mod))
    return lines


def main(argv: list[str]) -> int:
    surface = current_surface()
    if "--update" in argv:
        SNAPSHOT.write_text("\n".join(surface) + "\n", encoding="utf-8")
        print(f"wrote {len(surface)} entries to "
              f"{SNAPSHOT.relative_to(REPO)}")
        return 0
    if not SNAPSHOT.exists():
        print(f"missing snapshot {SNAPSHOT.relative_to(REPO)}; run "
              "`python tools/check_api.py --update` and commit it")
        return 1
    want = [l for l in SNAPSHOT.read_text(encoding="utf-8").splitlines()
            if l.strip()]
    added = sorted(set(surface) - set(want))
    removed = sorted(set(want) - set(surface))
    if not added and not removed:
        print(f"public surface OK ({len(surface)} entries, "
              f"{len(MODULES)} modules)")
        return 0
    for name in added:
        print(f"NEW export not in snapshot: {name}")
    for name in removed:
        print(f"snapshot entry no longer exported: {name}")
    print("\npublic surface drifted; if intentional, run "
          "`python tools/check_api.py --update` and commit the snapshot")
    return 1


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
