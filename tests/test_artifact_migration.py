"""v3 -> v4 artifact migration: old artifacts load and serve bit-exact.

Layout v4 (nibble-packed int4 planes + per-plane occupancy maps) changed
what ``DeployArtifact.save`` writes, but every v1-v3 artifact in the
fleet must keep loading: ``load()`` migrates standard-pack params
in-memory (``_migrate_pre_v4``) — unpacked int4 planes nibble-pack where
the packed axis is even, and every digit-plane leaf gains its ``*_occ``
sibling — and the migrated tree must equal a fresh v4 pack leaf-for-leaf
and serve bit-exactly. Backends with their own pack format (binary)
pass through untouched.

The v3 fixtures are fabricated from today's packer by inverting the v4
storage transform (unpack nibbles, drop occ) and stamping
``layout_version: 3`` — byte-equivalent to what the PR 9 writer
produced.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import CIMConfig, DeployArtifact, QuantConv2d, QuantLinear
from repro.core.nibble import is_nibble_packed, unpack_nibbles


def _cfg(mode="deploy", **kw):
    base = dict(enabled=True, mode=mode, weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=4, array_rows=32, array_cols=32,
                pack_dtype="int4")
    base.update(kw)
    return CIMConfig(**base)


def _downgrade_params(tree):
    """Invert the v4 storage transform: nibble planes back to dense int4,
    occupancy maps dropped — the exact leaf set a v3 writer stored."""
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            if k.endswith("_occ"):
                continue
            if isinstance(v, (dict, list, tuple)):
                out[k] = _downgrade_params(v)
            elif k.endswith("_digits") and is_nibble_packed(v):
                out[k] = unpack_nibbles(jnp.asarray(v)).astype(jnp.int4)
            else:
                out[k] = v
        return out
    if isinstance(tree, (list, tuple)):
        return [_downgrade_params(v) for v in tree]
    return tree


def _write_v3(art, path):
    """Persist ``art`` as its v3 ancestor (dense planes, no occ, header
    stamped layout_version 3)."""
    v3 = dataclasses.replace(art, params=_downgrade_params(art.params),
                             layout_version=3)
    v3.save(path)
    with open(os.path.join(path, "artifact.json")) as f:
        head = json.load(f)
    assert head["layout_version"] == 3
    return v3


def _assert_trees_equal(a, b):
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _no_occ_keys(tree):
    if isinstance(tree, dict):
        return all(not k.endswith("_occ") and _no_occ_keys(v)
                   for k, v in tree.items())
    if isinstance(tree, (list, tuple)):
        return all(_no_occ_keys(v) for v in tree)
    return True


def test_v3_linear_artifact_loads_as_v4_and_serves_bit_exact(tmp_path):
    cfg = _cfg()
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (6, 96)))
    h = QuantLinear(96, 40, cfg).init(jax.random.PRNGKey(0)).calibrate(x)
    art = h.pack()                                 # fresh v4
    assert is_nibble_packed(art.params["w_digits"])
    assert "w_occ" in art.params

    path = str(tmp_path / "v3")
    _write_v3(art, path)
    loaded = DeployArtifact.load(path)

    # migrated in-memory to the v4 layout, leaf-for-leaf == fresh pack
    assert loaded.layout_version == 4
    _assert_trees_equal(loaded.params, art.params)

    y_v4 = api.linear(x, art.params, art.config, compute_dtype=jnp.float32)
    y_mig = api.linear(x, loaded.params, loaded.config,
                       compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_v4), np.asarray(y_mig))


@pytest.mark.parametrize("array_rows", [36, 32])   # cpa 4 (packs) / 3 (odd)
def test_v3_conv_artifact_migrates(array_rows, tmp_path):
    cfg = _cfg(array_rows=array_rows)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 9, 9, 12)))
    h = (QuantConv2d(3, 3, 12, 20, cfg)
         .init(jax.random.PRNGKey(0)).calibrate(x))
    art = h.pack()
    packs = array_rows == 36                       # even c_per_array only
    assert is_nibble_packed(art.params["w_digits"]) == packs

    path = str(tmp_path / "v3")
    _write_v3(art, path)
    loaded = DeployArtifact.load(path)

    assert loaded.layout_version == 4
    assert is_nibble_packed(loaded.params["w_digits"]) == packs
    assert "w_occ" in loaded.params
    _assert_trees_equal(loaded.params, art.params)

    y_v4 = api.conv2d(x, art.params, art.config, compute_dtype=jnp.float32)
    y_mig = api.conv2d(x, loaded.params, loaded.config,
                       compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_v4), np.asarray(y_mig))


def test_v3_model_artifact_migrates_nested_tree(tmp_path):
    """A whole-model tree (nested dicts incl. non-CIM leaves) migrates
    node-by-node: every digit plane gains occ, nibble planes repack."""
    from repro.configs.registry import get_config
    from repro.models.registry import get_model
    from repro.nn import init_params
    cfg = get_config("llama3-8b", reduced=True, cim=_cfg(mode="emulate")) \
        .replace(compute_dtype="float32", remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    art = api.model_artifact(params, cfg.cim)

    path = str(tmp_path / "v3")
    _write_v3(art, path)
    loaded = DeployArtifact.load(path)

    assert loaded.layout_version == 4
    _assert_trees_equal(loaded.params, art.params)

    dcfg = cfg.replace(cim=loaded.config)
    y_v4 = np.asarray(model.forward(art.params, tokens, dcfg))
    y_mig = np.asarray(model.forward(loaded.params, tokens, dcfg))
    np.testing.assert_array_equal(y_v4, y_mig)


def test_v3_binary_artifact_passes_through_untouched(tmp_path):
    """The binary backend owns its pack format: migration must not graft
    occupancy maps or re-dtype its planes."""
    cfg = _cfg("binary")
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (6, 96)))
    h = QuantLinear(96, 40, cfg).init(jax.random.PRNGKey(0)).calibrate(x)
    art = h.pack()
    assert _no_occ_keys(art.params)

    path = str(tmp_path / "v3")
    # binary's v3 params == its v4 params; only the header version moves
    v3 = dataclasses.replace(art, layout_version=3)
    v3.save(path)
    loaded = DeployArtifact.load(path)

    assert loaded.layout_version == 4      # header upgraded...
    assert _no_occ_keys(loaded.params)     # ...params untouched
    _assert_trees_equal(loaded.params, art.params)
    y_a = api.linear(x, art.params, art.config, compute_dtype=jnp.float32)
    y_l = api.linear(x, loaded.params, loaded.config,
                     compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_l))
