"""ResNet on the CIM conv framework (the paper's own architecture)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity
from repro.models.resnet import ResNetConfig, calibrate, forward, init


def _cfg(depth=20, **cim_kw):
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=3, cell_bits=1,
                    act_bits=3, psum_bits=4, array_rows=128, array_cols=128,
                    weight_granularity=Granularity.COLUMN,
                    psum_granularity=Granularity.COLUMN,
                    act_signed=False, **cim_kw)
    widths = (8, 16, 32) if depth == 20 else (16, 32, 64)
    return ResNetConfig(name=f"resnet{depth}-test", depth=depth,
                        n_classes=10, widths=widths, in_hw=16, cim=cim)


def test_resnet20_smoke_train_eval():
    cfg = _cfg()
    params, state = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    params = calibrate(params, state, x, cfg)
    logits, new_state = forward(params, state, x, cfg, train=True)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # BN running stats moved
    moved = float(jnp.sum(jnp.abs(new_state["stem_bn"]["mean"]
                                  - state["stem_bn"]["mean"])))
    assert moved > 0
    logits_eval, _ = forward(params, new_state, x, cfg, train=False)
    assert bool(jnp.all(jnp.isfinite(logits_eval)))


def test_resnet_grads_and_one_sgd_step_reduces_loss():
    cfg = _cfg()
    params, state = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16, 3))
    y = jnp.arange(8) % 10
    params = calibrate(params, state, x, cfg)

    def loss_fn(p):
        logits, _ = forward(p, state, x, cfg, train=True)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    l0, g = jax.value_and_grad(loss_fn)(params)
    gn = sum(float(jnp.linalg.norm(l)) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # STE makes the loss piecewise-constant in the SCALE params (single
    # steps can cross rounding thresholds non-monotonically); the weight
    # gradient must still be a descent direction.
    import jax.tree_util as jtu

    def w_step(eps):
        def f(path, p, gg):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            return p if name in ("s_p", "s_a", "s_w") else p - eps * gg
        return jtu.tree_map_with_path(f, params, g)

    improved = any(float(loss_fn(w_step(eps))) < float(l0)
                   for eps in (0.01, 0.001))
    assert improved


def test_resnet_variation_noise_changes_outputs_boundedly():
    cfg = _cfg()
    cfg = ResNetConfig(name=cfg.name, depth=20, n_classes=10,
                       widths=cfg.widths, in_hw=16,
                       cim=cfg.cim.replace(variation_std=0.2))
    params, state = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    params = calibrate(params, state, x, cfg)
    clean, _ = forward(params, state, x, cfg, train=False)
    noisy, _ = forward(params, state, x, cfg, train=False,
                       variation_key=jax.random.PRNGKey(7))
    d = float(jnp.linalg.norm(noisy - clean) / jnp.linalg.norm(clean))
    assert 0 < d < 1.5


def test_resnet18_shapes():
    cfg = ResNetConfig(name="r18", depth=18, n_classes=100, in_hw=32,
                       cim=CIMConfig(enabled=False))
    params, state = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits, _ = forward(params, state, x, cfg, train=True)
    assert logits.shape == (2, 100)
