"""Trainer invariants: loss decreases on learnable data, microbatch
accumulation equivalence, optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.data.pipeline import make_lm_pipeline
from repro.models.registry import get_model
from repro.nn import init_params
from repro.optim.optimizer import make_optimizer
from repro.optim.schedule import cosine_warmup
from repro.train.trainer import make_train_step


def test_loss_decreases_on_markov_stream():
    cfg = get_config("olmo-1b", reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    run = RunConfig(lr=3e-3, total_steps=60, warmup_steps=5)
    init_state, train_step = make_train_step(model, cfg, run)
    opt_state = init_state(params)
    step = jax.jit(train_step, donate_argnums=(0, 1))
    pipe = make_lm_pipeline(vocab=cfg.vocab, seq_len=32, global_batch=8)
    losses = []
    for i, raw in zip(range(40), pipe):
        params, opt_state, m = step(params, opt_state,
                                    {"tokens": jnp.asarray(raw["tokens"])})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, \
        (losses[:5], losses[-5:])


def test_accum_equivalence():
    """accum_steps=2 must produce (numerically) the same update as a
    single full-batch step."""
    cfg = get_config("qwen3-0.6b", reduced=True).replace(
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)
    batch = {"tokens": tokens}

    outs = {}
    for accum in (1, 2):
        run = RunConfig(lr=1e-2, total_steps=10, warmup_steps=1,
                        accum_steps=accum, grad_clip=0.0)
        init_state, train_step = make_train_step(model, cfg, run)
        p, o, m = train_step(params, init_state(params), batch)
        outs[accum] = (p, float(m["loss"]))
    assert abs(outs[1][1] - outs[2][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[2][0])):
        # accumulation changes the float summation order; the Adam update
        # direction amplifies the resulting ulp-level grad differences on
        # near-zero second moments, so allow a slightly looser rel tol
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=1e-4)


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_reduce_quadratic(name):
    opt = make_optimizer(name)
    params = {"w": jnp.asarray([2.0, -3.0, 1.5])}
    state = opt.init(params)
    lr = {"adamw": 0.1, "adafactor": 0.3, "sgdm": 0.1}[name]
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.step(params, grads, state, lr,
                                    weight_decay=0.0, grad_clip=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


def test_adamw_state_dtype_bf16():
    opt = make_optimizer("adamw")
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = opt.init(params, jnp.bfloat16)
    assert state["m"]["w"].dtype == jnp.bfloat16
    params2, state, _ = opt.step(params, {"w": jnp.ones((4,))}, state, 1e-2)
    assert params2["w"].dtype == jnp.float32
    assert state["v"]["w"].dtype == jnp.bfloat16


def test_grad_clipping_bounds_update():
    opt = make_optimizer("sgdm")
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    huge = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    p2, _, gnorm = opt.step(params, huge, state, lr=1.0, momentum=0.0,
                            weight_decay=0.0, grad_clip=1.0)
    assert float(gnorm) > 1e5
    assert float(jnp.linalg.norm(p2["w"])) <= 1.0 + 1e-5


def test_cosine_warmup_schedule():
    lr0 = cosine_warmup(jnp.asarray(0), base_lr=1.0, warmup_steps=10,
                        total_steps=100)
    lr_mid = cosine_warmup(jnp.asarray(10), base_lr=1.0, warmup_steps=10,
                           total_steps=100)
    lr_end = cosine_warmup(jnp.asarray(100), base_lr=1.0, warmup_steps=10,
                           total_steps=100)
    assert float(lr0) < float(lr_mid)
    assert float(lr_end) == pytest.approx(0.1, abs=1e-3)
