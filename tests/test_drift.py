"""Self-healing serving: drift model, online detection, recalibration
(DESIGN.md §11).

Covers the PR 6 loop end to end: the time-indexed drift process agrees
across emulate/deploy under a shared key (same 1e-4 contract as static
variation), persistent components persist across the request clock while
the read component re-draws, ScaleDelta fit/apply/persist round-trips
bit-exactly and rejects version mismatches with typed errors, and the
serving engine detects drift, degrades, and recalibrates in place.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ARTIFACT_LAYOUT_VERSION, SCALE_DELTA_VERSION,
                       ArtifactVersionError, CIMConfig, DeployArtifact,
                       calibrate_linear, init_linear, linear, pack_linear)
from repro.core.variation import (DriftSchedule, DriftState, drift_field,
                                  drift_tree, perturb_packed)
from repro.eval.recalibrate import (ScaleDelta, apply_scale_delta,
                                    apply_scale_delta_params,
                                    fit_scale_delta)
from repro.serve.health import DriftMonitor, HealthConfig


def _cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=4, array_rows=32, array_cols=32)
    base.update(kw)
    return CIMConfig(**base)


def _setup(cfg, k=70, n=24, b=8, seed=0):
    p = init_linear(jax.random.PRNGKey(seed), k, n, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, k)) * 0.5
    return calibrate_linear(x, p, cfg), x


def _sched(**kw):
    base = dict(read_sigma=0.02, read_rate=0.0, cell_rate=2e-4,
                col_rate=1e-3)
    base.update(kw)
    return DriftSchedule(**base)


# ---------------------------------------------------------------------------
# drift field semantics
# ---------------------------------------------------------------------------

def test_drift_field_deterministic_and_time_indexed():
    key = jax.random.PRNGKey(3)
    shape = (2, 2, 32, 16)
    st = _sched().at(100)
    f1 = drift_field(key, shape, st)
    f2 = drift_field(key, shape, st)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    # the read component re-draws per t -> different field at another t
    f3 = drift_field(key, shape, _sched().at(101))
    assert np.abs(np.asarray(f3) - np.asarray(f1)).max() > 0


def test_drift_persistent_components_persist_across_t():
    """With the read component off, the cell/column fields at t2 are a
    deterministic rescaling of the fields at t1 (same theta draws):
    log f(t) = t * (rate * theta), so log f(t2)/log f(t1) == t2/t1."""
    key = jax.random.PRNGKey(5)
    shape = (2, 2, 32, 16)
    sched = DriftSchedule(cell_rate=1e-3, col_rate=2e-3)
    l1 = np.log(np.asarray(drift_field(key, shape, sched.at(100))))
    l2 = np.log(np.asarray(drift_field(key, shape, sched.at(200))))
    np.testing.assert_allclose(l2, 2.0 * l1, rtol=1e-4, atol=1e-6)


def test_drift_zero_schedule_is_noop():
    cfg = _cfg()
    p, x = _setup(cfg)
    packed = pack_linear(p, cfg)
    tree = {"lin": packed}
    out = drift_tree(tree, jax.random.PRNGKey(0), DriftSchedule().at(500))
    # statically-zero schedule: identical objects, not merely equal values
    assert out["lin"]["w_digits"] is packed["w_digits"]


def test_drift_tree_deterministic_and_column_structure():
    cfg = _cfg()
    p, x = _setup(cfg)
    packed = pack_linear(p, cfg)
    tree = {"lin": packed}
    st = DriftSchedule(col_rate=1e-3).at(300)
    d1 = drift_tree(tree, jax.random.PRNGKey(9), st)
    d2 = drift_tree(tree, jax.random.PRNGKey(9), st)
    np.testing.assert_array_equal(np.asarray(d1["lin"]["w_digits"]),
                                  np.asarray(d2["lin"]["w_digits"]))
    # pure column drift: the field is constant down each physical column
    w0 = np.asarray(packed["w_digits"], np.float32)
    wd = np.asarray(d1["lin"]["w_digits"], np.float32)
    ratio = np.where(w0 != 0, wd / np.where(w0 == 0, 1, w0), np.nan)
    # per (split, tile, column): all non-NaN row ratios agree (all-zero
    # columns carry no signal and are skipped)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        col_spread = np.nanmax(ratio, axis=-2) - np.nanmin(ratio, axis=-2)
        assert np.nanmax(col_spread) < 1e-5


@pytest.mark.parametrize("use_kernel", [True, False])
def test_drift_emulate_deploy_agree(use_kernel):
    """Same key + same DriftState => emulate and deploy see the same chip
    (the §8 variation contract, now time-indexed)."""
    cfg = _cfg()
    p, x = _setup(cfg)
    vk = jax.random.PRNGKey(42)
    st = _sched().at(250)
    y_em = linear(x, p, cfg, variation_key=vk, variation_std=st,
                  compute_dtype=jnp.float32)
    pd = pack_linear(p, cfg)
    y_dep = linear(x, pd, cfg.replace(mode="deploy", use_kernel=use_kernel),
                   variation_key=vk, variation_std=st,
                   compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_dep), np.asarray(y_em),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# online detection
# ---------------------------------------------------------------------------

def test_monitor_detects_shift_and_resets_on_recal():
    rng = np.random.RandomState(0)
    mon = DriftMonitor(HealthConfig(warmup=16, soft_threshold=4.0,
                                    hard_threshold=8.0))
    for _ in range(16):
        mon.observe({"m": 1.0 + 0.05 * rng.randn()})
    assert mon.warmed_up and not mon.drifted
    for _ in range(20):
        mon.observe({"m": 2.0 + 0.05 * rng.randn()})
    assert mon.drifted and mon.drifted_at is not None
    assert mon.hard_drifted
    mon.note_recalibration()
    assert mon.recalibrations == 1 and mon.score == 0.0 and not mon.drifted
    snap = mon.snapshot()
    assert snap["steps"] == 36 and "m" in snap["stats"]


def test_monitor_ignores_nonfinite_and_scales_floor():
    mon = DriftMonitor(HealthConfig(warmup=4))
    for v in (1.0, 1.0, 1.0, 1.0):
        mon.observe({"m": v})
    s = mon.observe({"m": float("nan")})
    assert np.isfinite(s)                 # nan observation doesn't poison
    # constant baseline: std floor keeps z finite
    s = mon.observe({"m": 1.5})
    assert np.isfinite(s) and s > 0


def test_monitor_no_latch_before_warmup():
    """Scores computed while any baseline is still forming never latch —
    even against a zero threshold (PR 7 edge-case fix)."""
    mon = DriftMonitor(HealthConfig(warmup=8, soft_threshold=0.0,
                                    hard_threshold=0.0))
    for i in range(7):
        mon.observe({"m": float(i * 100)})         # wild swings mid-warmup
    assert not mon.warmed_up
    assert not mon.drifted and not mon.hard_drifted
    assert mon.drifted_at is None
    # a statistic that first appears late re-closes the gate
    mon2 = DriftMonitor(HealthConfig(warmup=2, soft_threshold=0.0,
                                     hard_threshold=0.0))
    for _ in range(3):
        mon2.observe({"a": 1.0})
    assert mon2.drifted                            # zero threshold, warmed
    mon2.observe({"a": 1.0, "b": 5.0})             # "b" starts its baseline
    assert not mon2.warmed_up and not mon2.hard_drifted


def test_monitor_warmup_zero_is_safe():
    """warmup=0 historically crashed (no baseline, ewma=None in the
    post-warmup branch); the effective warmup floor is one observation."""
    mon = DriftMonitor(HealthConfig(warmup=0))
    s = mon.observe({"m": 1.0})
    assert np.isfinite(s)
    assert not mon.drifted and not mon.hard_drifted
    s = mon.observe({"m": 1.1})
    assert np.isfinite(s)


def test_monitor_recal_hysteresis_deterministic():
    """observe() immediately after note_recalibration() must not latch
    hard_drifted: the grace window suppresses both flags for exactly
    ``hysteresis`` observations, then they re-assert on the same step
    for the same input stream."""
    cfgm = HealthConfig(warmup=4, soft_threshold=1.0, hard_threshold=1.0,
                        hysteresis=3, ewma=1.0)
    mon = DriftMonitor(cfgm)
    for _ in range(4):
        mon.observe({"m": 1.0})
    mon.observe({"m": 100.0})
    assert mon.hard_drifted and mon.drifted_at is not None
    mon.note_recalibration()
    assert mon.drifted_at is None and mon.in_grace
    assert not mon.hard_drifted                    # immediately after recal
    latched_at = None
    for i in range(1, 6):
        mon.observe({"m": 100.0})
        if latched_at is None and mon.hard_drifted:
            latched_at = i
    # the hysteresis-th observation after the recal is the first that can
    # re-assert the flags — deterministically
    assert latched_at == cfgm.hysteresis
    assert mon.drifted_at is not None


# ---------------------------------------------------------------------------
# recalibration math
# ---------------------------------------------------------------------------

def test_recalibration_recovers_column_drift():
    """Pure column-gain drift is recovered to the psum re-rounding floor:
    the recalibrated deploy output is much closer to clean than the
    drifted one (exact recovery is impossible — the ADC re-rounds)."""
    cfg = _cfg(psum_bits=6)
    p, x = _setup(cfg)
    packed = pack_linear(p, cfg)
    dcfg = cfg.replace(mode="deploy")
    tree = {"lin": packed}
    st = DriftSchedule(col_rate=1e-3).at(400)   # sigma_col = 0.4
    drifted = drift_tree(tree, jax.random.PRNGKey(11), st)

    y_clean = linear(x, packed, dcfg, compute_dtype=jnp.float32)
    y_drift = linear(x, drifted["lin"], dcfg, compute_dtype=jnp.float32)
    delta = fit_scale_delta(tree, drifted, key=jax.random.PRNGKey(1),
                            probes=32)
    recal = apply_scale_delta_params(drifted, delta)
    assert "deq_scale" in recal["lin"]
    y_recal = linear(x, recal["lin"], dcfg, compute_dtype=jnp.float32)

    e_drift = float(jnp.linalg.norm(y_drift - y_clean))
    e_recal = float(jnp.linalg.norm(y_recal - y_clean))
    assert e_recal < 0.34 * e_drift, (e_drift, e_recal)


def test_scale_delta_roundtrip_bit_exact(tmp_path):
    cfg = _cfg()
    p, x = _setup(cfg)
    packed = pack_linear(p, cfg)
    tree = {"lin": packed}
    drifted = drift_tree(tree, jax.random.PRNGKey(2), _sched().at(200))
    delta = fit_scale_delta(tree, drifted, key=jax.random.PRNGKey(3),
                            meta={"t": 200})
    path = os.path.join(tmp_path, "delta")
    delta.save(path)
    loaded = ScaleDelta.load(path)
    assert loaded.delta_version == SCALE_DELTA_VERSION
    assert loaded.layout_version == delta.layout_version
    assert loaded.meta["t"] == 200
    a = apply_scale_delta_params(tree, delta)
    b = apply_scale_delta_params(tree, loaded)
    np.testing.assert_array_equal(np.asarray(a["lin"]["s_p"]),
                                  np.asarray(b["lin"]["s_p"]))
    np.testing.assert_array_equal(np.asarray(a["lin"]["deq_scale"]),
                                  np.asarray(b["lin"]["deq_scale"]))


# ---------------------------------------------------------------------------
# versioning: typed errors, stale deltas
# ---------------------------------------------------------------------------

def _artifact(tmp_path):
    cfg = _cfg()
    p, _ = _setup(cfg)
    packed = pack_linear(p, cfg)
    art = DeployArtifact(kind="linear", params=packed,
                         config=cfg.replace(mode="deploy"))
    d = os.path.join(tmp_path, "art")
    art.save(d)
    return art, d


def test_load_rejects_future_layout_with_typed_error(tmp_path):
    _, d = _artifact(tmp_path)
    jpath = os.path.join(d, "artifact.json")
    with open(jpath) as f:
        head = json.load(f)
    head["layout_version"] = ARTIFACT_LAYOUT_VERSION + 7
    with open(jpath, "w") as f:
        json.dump(head, f)
    with pytest.raises(ArtifactVersionError) as ei:
        DeployArtifact.load(d)
    msg = str(ei.value)
    assert "layout_version" in msg
    assert str(ARTIFACT_LAYOUT_VERSION + 7) in msg
    assert str(ARTIFACT_LAYOUT_VERSION) in msg
    from repro.api.artifact import _LAYOUT_WRITERS
    assert _LAYOUT_WRITERS[ARTIFACT_LAYOUT_VERSION] in msg  # names the writer PR
    # typed: still catchable as ValueError (pre-PR-6 callers)
    assert isinstance(ei.value, ValueError)


def test_future_delta_version_rejected(tmp_path):
    cfg = _cfg()
    p, _ = _setup(cfg)
    tree = {"lin": pack_linear(p, cfg)}
    drifted = drift_tree(tree, jax.random.PRNGKey(2), _sched().at(50))
    delta = fit_scale_delta(tree, drifted, key=jax.random.PRNGKey(3))
    newer = dataclasses.replace(delta,
                                delta_version=SCALE_DELTA_VERSION + 1)
    path = os.path.join(tmp_path, "delta")
    newer.save(path)
    with pytest.raises(ArtifactVersionError, match="delta_version"):
        ScaleDelta.load(path)


def test_stale_delta_rejected_on_apply(tmp_path):
    art, _ = _artifact(tmp_path)
    tree = art.params
    drifted = drift_tree({"p": tree}, jax.random.PRNGKey(2),
                         _sched().at(50))["p"]
    delta = fit_scale_delta(tree, drifted, key=jax.random.PRNGKey(3))
    stale = dataclasses.replace(delta,
                                layout_version=art.layout_version + 1)
    with pytest.raises(ArtifactVersionError, match="layout_version"):
        apply_scale_delta(art, stale)
    # fresh delta applies; re-applying on the recalibrated artifact is
    # refused (deltas are absolute)
    recal = apply_scale_delta(art, delta)
    assert recal.meta["delta_version"] == delta.delta_version
    with pytest.raises(ValueError, match="absolute"):
        apply_scale_delta(recal, delta)


# ---------------------------------------------------------------------------
# engine integration (tiny LM)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.api import model_artifact
    from repro.configs.registry import get_config
    from repro.core.granularity import Granularity as G
    from repro.models.registry import get_model
    from repro.nn import init_params
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    weight_granularity=G.COLUMN, psum_granularity=G.COLUMN)
    cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    art = model_artifact(params, cim)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 5)
                                               ).astype(np.int32)
    return art, cfg, prompts


def test_engine_zero_schedule_matches_plain(lm_setup):
    from repro.serve import engine_from_artifact
    art, cfg, prompts = lm_setup
    eng0 = engine_from_artifact(art, cfg, batch_size=2, max_len=32)
    eng1 = engine_from_artifact(art, cfg, batch_size=2, max_len=32,
                                drift_key=jax.random.PRNGKey(7),
                                drift_schedule=DriftSchedule())
    out0 = eng0.generate_batch(prompts, 6)
    out1 = eng1.generate_batch(prompts, 6)
    np.testing.assert_array_equal(out0, out1)


def test_engine_drift_determinism(lm_setup):
    """Same drift key + same request schedule => bit-identical tokens."""
    from repro.serve import engine_from_artifact
    art, cfg, prompts = lm_setup
    sched = _sched()

    def run():
        eng = engine_from_artifact(art, cfg, batch_size=2, max_len=32,
                                   drift_key=jax.random.PRNGKey(7),
                                   drift_schedule=sched)
        eng.t = 300
        return eng.generate_batch(prompts, 6)
    np.testing.assert_array_equal(run(), run())


def test_engine_health_and_recalibrate(lm_setup):
    from repro.serve import engine_from_artifact
    art, cfg, prompts = lm_setup
    mon = DriftMonitor(HealthConfig(warmup=4))
    eng = engine_from_artifact(art, cfg, batch_size=2, max_len=32,
                               drift_key=jax.random.PRNGKey(7),
                               drift_schedule=_sched(), health=mon)
    eng.generate_batch(prompts, 6)
    h = eng.health()
    # prefill tick + 5 decode ticks for 6 generated tokens
    assert h["drifting"] and h["t"] == 6 and h["steps"] > 0
    delta = eng.recalibrate(probes=8)
    assert set(delta.gains)                       # one gain per CIM node
    assert eng.health()["recalibrations"] == 1
    assert "deq_scale" in str(jax.tree_util.tree_structure(eng.params))
    # engine still serves after the swap
    out = eng.generate_batch(prompts, 4)
    assert out.shape == (2, 4)


def test_engine_hard_drift_falls_back(lm_setup):
    from repro.serve import engine_from_artifact
    art, cfg, prompts = lm_setup
    mon = DriftMonitor(HealthConfig(warmup=2, soft_threshold=0.0,
                                    hard_threshold=0.0))
    eng = engine_from_artifact(art, cfg, batch_size=2, max_len=32,
                               drift_key=jax.random.PRNGKey(7),
                               drift_schedule=_sched(), health=mon)
    eng.generate_batch(prompts, 6)
    assert eng.fallback_active                    # zero threshold trips
    assert eng.health()["hard_events"] >= 1
    # fallback serves the digital reference on pristine planes
    out = eng.generate_batch(prompts, 4)
    assert out.shape == (2, 4)
    eng.recalibrate(probes=8)
    assert not eng.fallback_active


def test_engine_mesh_mismatch_fails_loudly(lm_setup):
    from repro.nn.module import current_rules, set_activation_rules
    from repro.serve import engine_from_artifact
    art, cfg, prompts = lm_setup
    eng = engine_from_artifact(art, cfg, batch_size=2, max_len=32)
    mesh = jax.make_mesh((1,), ("model",))
    set_activation_rules(current_rules(), mesh)
    try:
        with pytest.raises(RuntimeError, match="session mesh"):
            eng.generate_batch(prompts, 2)
        with pytest.raises(RuntimeError, match="session mesh"):
            eng.submit([1, 2], 2), eng.step()
    finally:
        set_activation_rules(None, None)
    # back under the build mesh: serves again
    out = eng.generate_batch(prompts, 2)
    assert out.shape == (2, 2)
