"""CIM linear: emulate/deploy equivalence, granularity behaviour, LSQ
gradients, variation robustness ordering (paper core claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import calibrate_linear as calibrate_cim
from repro.api import init_linear as init_cim_linear
from repro.api import linear as cim_linear
from repro.api import pack_linear as pack_deploy
from repro.core import CIMConfig, Granularity
from repro.core.cim_linear import weight_scales_from


def _cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=4, array_rows=32, array_cols=32)
    base.update(kw)
    return CIMConfig(**base)


def _setup(cfg, k=70, n=24, b=8, seed=0):
    key = jax.random.PRNGKey(seed)
    p = init_cim_linear(key, k, n, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, k)) * 0.5
    p = calibrate_cim(x, p, cfg)
    return p, x


@settings(max_examples=12, deadline=None)
@given(
    wb_cb=st.sampled_from([(4, 2), (3, 1), (2, 2), (8, 4)]),
    pb=st.sampled_from([1, 3, 6]),
    g=st.sampled_from(list(Granularity)),
    seed=st.integers(0, 1000),
)
def test_emulate_equals_deploy(wb_cb, pb, g, seed):
    wb, cb = wb_cb
    cfg = _cfg(weight_bits=wb, cell_bits=cb, psum_bits=pb,
               weight_granularity=g, psum_granularity=g)
    p, x = _setup(cfg, seed=seed)
    y_em = cim_linear(x, p, cfg, compute_dtype=jnp.float32)
    pd = pack_deploy(p, cfg)
    y_dep = cim_linear(x, pd, cfg.replace(mode="deploy"),
                       compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_em), np.asarray(y_dep),
                               rtol=1e-4, atol=1e-4)


def test_quantization_error_decreases_with_bits():
    errs = []
    for wb, cb, pb, ab in [(2, 2, 2, 3), (4, 2, 4, 6), (8, 2, 8, 8)]:
        cfg = _cfg(weight_bits=wb, cell_bits=cb, psum_bits=pb, act_bits=ab)
        p, x = _setup(cfg)
        y_q = cim_linear(x, p, cfg, compute_dtype=jnp.float32)
        y_fp = cim_linear(x, p, cfg.replace(mode="off"),
                          compute_dtype=jnp.float32)
        errs.append(float(jnp.linalg.norm(y_q - y_fp)
                          / jnp.linalg.norm(y_fp)))
    assert errs[0] > errs[1] > errs[2], errs


def test_column_granularity_beats_layer_on_heterogeneous_weights():
    """The paper's Fig. 6 mechanism: per-column scales capture columns with
    very different magnitudes; a single layer scale cannot."""
    key = jax.random.PRNGKey(0)
    k, n, b = 64, 16, 32
    col_scales = jnp.logspace(-2, 0.5, n)[None, :]
    w = jax.random.normal(key, (k, n)) * col_scales
    x = jax.random.normal(jax.random.PRNGKey(1), (b, k))
    errs = {}
    for g in (Granularity.LAYER, Granularity.COLUMN):
        cfg = _cfg(weight_granularity=g, psum_granularity=g, array_rows=64,
                   weight_bits=3, cell_bits=1, psum_bits=4, act_bits=8)
        p = init_cim_linear(key, k, n, cfg)
        p["w"] = w
        p["s_w"] = weight_scales_from(w, cfg)
        p = calibrate_cim(x, p, cfg)
        y_q = cim_linear(x, p, cfg, compute_dtype=jnp.float32)
        y_fp = cim_linear(x, p, cfg.replace(mode="off"),
                          compute_dtype=jnp.float32)
        errs[g] = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert errs[Granularity.COLUMN] < errs[Granularity.LAYER], errs


def test_grads_flow_to_all_quant_params():
    cfg = _cfg()
    p, x = _setup(cfg)

    def loss(p):
        return jnp.sum(cim_linear(x, p, cfg, compute_dtype=jnp.float32) ** 2)
    g = jax.grad(loss)(p)
    for name in ("w", "s_w", "s_p", "s_a"):
        gn = float(jnp.linalg.norm(g[name]))
        assert np.isfinite(gn) and gn > 0, name


def test_psum_quant_off_is_more_accurate():
    cfg = _cfg(psum_bits=2)
    p, x = _setup(cfg)
    y_fp = cim_linear(x, p, cfg.replace(mode="off"), compute_dtype=jnp.float32)
    y_psq = cim_linear(x, p, cfg, compute_dtype=jnp.float32)
    y_nopsq = cim_linear(x, p, cfg.replace(psum_quant=False),
                         compute_dtype=jnp.float32)
    e_psq = float(jnp.linalg.norm(y_psq - y_fp))
    e_nopsq = float(jnp.linalg.norm(y_nopsq - y_fp))
    assert e_nopsq < e_psq


def test_variation_robustness_column_beats_layer():
    """Paper Fig. 10 mechanism: under log-normal cell noise, the
    column-quantized layer's TOTAL error vs the true (full-precision)
    computation stays far below layer-wise — per-column scales both
    represent heterogeneous columns accurately and localize the noise."""
    key = jax.random.PRNGKey(0)
    k, n, b = 64, 16, 64
    col_scales = jnp.logspace(-1.5, 0.5, n)[None, :]
    w = jax.random.normal(key, (k, n)) * col_scales
    x = jax.random.normal(jax.random.PRNGKey(1), (b, k))
    total_err = {}
    for g in (Granularity.LAYER, Granularity.COLUMN):
        cfg = _cfg(weight_granularity=g, psum_granularity=g,
                   weight_bits=4, cell_bits=2, psum_bits=6, act_bits=8,
                   array_rows=64, variation_std=0.3)
        p = init_cim_linear(key, k, n, cfg)
        p["w"] = w
        p["s_w"] = weight_scales_from(w, cfg)
        p = calibrate_cim(x, p, cfg)
        y_fp = cim_linear(x, p, cfg.replace(mode="off"),
                          compute_dtype=jnp.float32)
        errs = []
        for i in range(8):
            y = cim_linear(x, p, cfg,
                           variation_key=jax.random.PRNGKey(100 + i),
                           compute_dtype=jnp.float32)
            errs.append(float(jnp.linalg.norm(y - y_fp)
                              / jnp.linalg.norm(y_fp)))
        total_err[g] = np.mean(errs)
    assert total_err[Granularity.COLUMN] < total_err[Granularity.LAYER], \
        total_err
