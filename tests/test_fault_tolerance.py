"""Fault tolerance: crash injection + resume reproduces the uninterrupted
run; straggler policy; compressed gradient sync."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.data.pipeline import make_lm_pipeline
from repro.models.registry import get_model
from repro.nn import init_params
from repro.runtime.fault_tolerance import (FaultTolerantLoop, InjectedFailure,
                                           TrainLoopState)
from repro.runtime.straggler import StragglerMonitor
from repro.train.trainer import make_train_step


def _setup(tmp_path, ckpt_every=5):
    cfg = get_config("qwen3-0.6b", reduced=True).replace(
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    run = RunConfig(lr=1e-3, total_steps=20, warmup_steps=2)
    init_state, train_step = make_train_step(model, cfg, run)
    train_step = jax.jit(train_step)

    def fresh():
        params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
        return TrainLoopState(params=params, opt_state=init_state(params),
                              step=0)

    def batches():
        pipe = make_lm_pipeline(vocab=cfg.vocab, seq_len=16, global_batch=4)
        for raw in pipe:
            yield {"tokens": jnp.asarray(raw["tokens"])}

    loop = FaultTolerantLoop(str(tmp_path), checkpoint_every=ckpt_every,
                             async_save=False)
    return loop, fresh, train_step, batches


def _data_for(step_start, batches_fn):
    """Data pipeline is deterministic in step: skip to the right offset."""
    gen = batches_fn()
    for _ in range(step_start):
        next(gen)
    return gen


def test_crash_and_resume_matches_uninterrupted(tmp_path):
    loop, fresh, train_step, batches = _setup(tmp_path / "a", ckpt_every=5)

    # uninterrupted reference
    ref_state = loop.run(fresh(), train_step, batches(), total_steps=12)

    # crashed-and-resumed run in a different directory
    loop2, fresh2, train_step2, batches2 = _setup(tmp_path / "b",
                                                  ckpt_every=5)
    with pytest.raises(InjectedFailure):
        loop2.run(fresh2(), train_step2, batches2(), total_steps=12,
                  crash_at_step=7)
    # relaunch: resume from latest checkpoint (step 5), replay data from there
    st = loop2.resume_or_init(fresh2)
    assert st.step == 5
    st = loop2.run(st, train_step2, _data_for(st.step, batches2),
                   total_steps=12)
    assert st.step == ref_state.step == 12

    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(st.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_straggler_policy():
    mon = StragglerMonitor(window=32, warn_factor=1.5, crit_factor=3.0,
                           min_samples=4)
    crits = []
    mon.on_critical = lambda t, med: crits.append((t, med))
    for _ in range(10):
        assert mon.observe(1.0) == "ok"
    assert mon.observe(1.4) == "ok"
    assert mon.observe(1.8) == "warn"
    assert mon.observe(5.0) == "critical"
    assert mon.n_warn == 1 and mon.n_crit == 1 and len(crits) == 1
    # stragglers don't poison the median
    assert mon.median() == pytest.approx(1.0, abs=0.1)


def test_compressed_gradient_sync_shard_map():
    """int8 reduce-scatter/all-gather gradient sync inside shard_map is
    close to the exact mean, and error feedback captures the residual."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.nn.module import shard_map
    from repro.train.grad_compress import (compressed_psum_tree,
                                           init_error_feedback)

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 8)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (17,))}
    ef = init_error_feedback(g)

    fn = shard_map(
        functools.partial(compressed_psum_tree, axis_name="data"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False)   # error-feedback output is device-local state
    synced, ef2 = fn(g, ef)
    for k in g:
        # compression error bounded by the int8 step of each leaf
        step = float(jnp.max(jnp.abs(g[k]))) / 127.0
        np.testing.assert_allclose(np.asarray(synced[k]), np.asarray(g[k]),
                                   atol=step + 1e-6)
        # error feedback holds exactly the quantization residual
        np.testing.assert_allclose(np.asarray(g[k] - synced[k]),
                                   np.asarray(ef2[k]), atol=1e-6)


def test_emergency_state_packing():
    st = TrainLoopState(params={"w": jnp.ones(3)},
                        opt_state={"m": jnp.zeros(3)}, step=9)
    packed = FaultTolerantLoop._pack(st)
    assert int(packed["step"]) == 9 and "params" in packed
