"""Conv deploy path: fused Pallas kernel vs the emulate grouped conv.

The deploy contract (DESIGN.md §3): identical arithmetic to emulate
(tests assert to 1e-4), activations never tiled ``n_split``x (HLO
inspected), the partial-sum tensor never materialized in HBM.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import calibrate_conv as calibrate_cim_conv
from repro.api import conv2d as cim_conv2d
from repro.api import init_conv as init_cim_conv
from repro.api import pack_conv as pack_deploy_conv
from repro.api import pack_model
from repro.core import CIMConfig, Granularity, conv_tiling


def _cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=6, array_rows=64, array_cols=64,
                act_signed=False)
    base.update(kw)
    return CIMConfig(**base)


def _setup(cfg, kh=3, c_in=19, c_out=10, b=2, hw=8, stride=1,
           padding="SAME", seed=0):
    p = init_cim_conv(jax.random.PRNGKey(seed), kh, kh, c_in, c_out, cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (b, hw, hw, c_in)))
    p = calibrate_cim_conv(x, p, cfg, stride=stride, padding=padding)
    return p, x


def _assert_deploy_matches(p, x, cfg, *, stride=1, padding="SAME",
                           use_kernel=True):
    y_e = cim_conv2d(x, p, cfg, stride=stride, padding=padding,
                     compute_dtype=jnp.float32)
    dp = pack_deploy_conv(p, cfg)
    y_d = cim_conv2d(x, dp, cfg.replace(mode="deploy", use_kernel=use_kernel),
                     stride=stride, padding=padding,
                     compute_dtype=jnp.float32)
    assert y_d.shape == y_e.shape
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=1e-4, atol=1e-4)
    return y_d


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_deploy_matches_emulate_stride_padding(stride, padding, use_kernel):
    cfg = _cfg()
    p, x = _setup(cfg, stride=stride, padding=padding)
    _assert_deploy_matches(p, x, cfg, stride=stride, padding=padding,
                           use_kernel=use_kernel)


@pytest.mark.parametrize("g", list(Granularity))
def test_deploy_matches_emulate_granularity(g):
    cfg = _cfg(weight_granularity=g, psum_granularity=g)
    p, x = _setup(cfg)
    _assert_deploy_matches(p, x, cfg)


def test_deploy_sign_adc_psum_bits_1():
    """psum_bits == 1 is the binary (ADC-less) partial-sum mode."""
    cfg = _cfg(psum_bits=1)
    p, x = _setup(cfg)
    _assert_deploy_matches(p, x, cfg)


def test_deploy_odd_channel_slices():
    """c_in that doesn't fill k_tiles * c_per_array: array_rows=32, 3x3
    taps -> c_per_array=3; c_in=7 -> k_tiles=3 with 2 padded channels."""
    cfg = _cfg(array_rows=32, array_cols=32)
    t, cpa = conv_tiling(3, 3, 7, 6, 32, 32, 4, 2)
    assert cpa == 3 and t.k_tiles == 3 and t.k_tiles * cpa != 7
    p, x = _setup(cfg, c_in=7, c_out=6)
    _assert_deploy_matches(p, x, cfg)


def test_deploy_1x1_proj_stride2():
    """The ResNet downsampling projection: 1x1 kernel, stride 2."""
    cfg = _cfg(array_rows=16)
    p, x = _setup(cfg, kh=1, c_in=24, c_out=8, stride=2)
    _assert_deploy_matches(p, x, cfg, stride=2)


def test_deploy_int4_packing():
    cfg = _cfg(pack_dtype="int4")
    p, x = _setup(cfg)
    dp = pack_deploy_conv(p, cfg)
    assert dp["w_digits"].dtype == jnp.int4
    _assert_deploy_matches(p, x, cfg)


def test_packed_planes_carry_geometry():
    cfg = _cfg()
    p, _ = _setup(cfg)
    dp = pack_deploy_conv(p, cfg)
    t, cpa = conv_tiling(3, 3, 19, 10, cfg.array_rows, cfg.array_cols,
                         cfg.weight_bits, cfg.cell_bits)
    assert dp["w_digits"].shape == (t.n_split, t.k_tiles, 3, 3, cpa, 10)


def test_deploy_hlo_has_no_nsplit_activation_tile():
    """The emulate grouped conv materializes the activation channel-slices
    tiled n_split x (B, H, W, S*kt*cpa); the deploy lowering must not."""
    cfg = _cfg()                  # S=2, and for c_in=19: kt=3, cpa=7
    p, x = _setup(cfg)
    t, cpa = conv_tiling(3, 3, 19, 10, cfg.array_rows, cfg.array_cols,
                         cfg.weight_bits, cfg.cell_bits)
    # StableHLO shape text for the (B, H, W, S*kt*cpa) replicated tile
    marker = f"2x8x8x{t.n_split * t.k_tiles * cpa}x"

    hlo_e = jax.jit(lambda x_: cim_conv2d(
        x_, p, cfg, compute_dtype=jnp.float32)).lower(x).as_text()
    assert marker in hlo_e        # sanity: the marker identifies the tile

    dp = pack_deploy_conv(p, cfg)
    dcfg = cfg.replace(mode="deploy")
    hlo_d = jax.jit(lambda x_: cim_conv2d(
        x_, dp, dcfg, compute_dtype=jnp.float32)).lower(x).as_text()
    assert marker not in hlo_d


def test_deploy_variation_noise():
    """Cell variation applies to the packed digit planes too."""
    cfg = _cfg(variation_std=0.2)
    p, x = _setup(cfg)
    dp = pack_deploy_conv(p, cfg)
    dcfg = cfg.replace(mode="deploy")
    k = jax.random.PRNGKey(7)
    y1 = cim_conv2d(x, dp, dcfg, variation_key=k, compute_dtype=jnp.float32)
    y2 = cim_conv2d(x, dp, dcfg, variation_key=jax.random.PRNGKey(8),
                    compute_dtype=jnp.float32)
    assert bool(jnp.all(jnp.isfinite(y1)))
    assert float(jnp.max(jnp.abs(y1 - y2))) > 0   # noise actually applied


def test_resnet_pack_deploy_forward():
    from repro.models import resnet
    cim = _cfg()
    cfg = resnet.ResNetConfig(name="tiny", depth=20, n_classes=10,
                              widths=(8, 16), in_hw=8, cim=cim)
    params, state = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    params = resnet.calibrate(params, state, x, cfg)
    y_e, _ = resnet.forward(params, state, x, cfg, train=False)

    dp = pack_model(params, cfg.cim)
    dcfg = dataclasses.replace(cfg, cim=cim.replace(mode="deploy"))
    y_d, _ = resnet.forward(dp, state, x, dcfg, train=False)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=1e-4, atol=1e-4)


def test_layers_conv_specs_and_apply():
    from repro.models.layers import apply_conv, conv_specs
    from repro.nn.module import init_params

    cim = _cfg()
    sp = conv_specs(3, 3, 19, 10, cim=cim)
    assert set(sp) == {"w", "s_w", "s_p", "s_a"}
    dsp = conv_specs(3, 3, 19, 10, cim=cim.replace(mode="deploy"))
    t, cpa = conv_tiling(3, 3, 19, 10, cim.array_rows, cim.array_cols,
                         cim.weight_bits, cim.cell_bits)
    assert dsp["w_digits"].shape == (t.n_split, t.k_tiles, 3, 3, cpa, 10)

    # emulate params round-trip through pack + apply_conv deploy dispatch
    cfg = _cfg()
    p, x = _setup(cfg)
    y_e = apply_conv(p, x, cfg, compute_dtype=jnp.float32)
    dp = pack_deploy_conv(p, cfg)
    y_d = apply_conv(dp, x, cfg.replace(mode="deploy"),
                     compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=1e-4, atol=1e-4)
    # init_params materializes the deploy specs (zeros planes)
    dparams = init_params(dsp, jax.random.PRNGKey(0))
    assert dparams["w_digits"].dtype == jnp.int8
