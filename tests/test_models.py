"""Per-arch smoke tests (reduced configs): one forward + one train step on
CPU, asserting shapes and finiteness; decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.configs.registry import ARCHS, get_config
from repro.models.registry import frontend_input_shape, get_model
from repro.nn import init_params
from repro.train.trainer import make_train_step

B, T = 2, 16


def _batch(cfg, b=B, t=T, seed=1):
    out = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (b, t + 1),
                                        0, cfg.vocab)}
    fshape = frontend_input_shape(cfg, b)
    if fshape is not None:
        # raw mel frames / images under conv_frontend, stub embeds otherwise
        out["frontend"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), fshape) * 0.1
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits = model.forward(params, batch["tokens"][:, :-1], cfg,
                           batch.get("frontend"))
    exp_t = T + (cfg.n_frontend_tokens if cfg.family == "llava" else 0)
    assert logits.shape == (B, exp_t, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    run = RunConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    init_state, train_step = make_train_step(model, cfg, run)
    opt_state = init_state(params)
    params2, opt_state, metrics = jax.jit(train_step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v3-671b",
                                  "xlstm-1.3b", "zamba2-2.7b",
                                  "whisper-small"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True).replace(compute_dtype="float32",
                                                 remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    extra = None
    cache = model.init_cache(cfg, B, T + 4)
    if cfg.family == "whisper":
        from repro.models import whisper
        # raw log-mel frames through the conv stem (reduced config has
        # conv_frontend on); decode reuses the cached encoder states
        extra = jax.random.normal(jax.random.PRNGKey(2),
                                  frontend_input_shape(cfg, B)) * 0.1
        cache["enc_out"] = whisper.encode(params, extra, cfg)
    full = model.forward(params, tokens, cfg, extra)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1], cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - dec)) / jnp.max(jnp.abs(full)))
    assert rel < 5e-3, rel


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-2.7b"])
def test_prefill_matches_forward(arch):
    cfg = get_config(arch, reduced=True).replace(compute_dtype="float32",
                                                 remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full = model.forward(params, tokens, cfg)
    cache = model.init_cache(cfg, B, T + 4)
    pre, _ = model.decode_step(params, cache, tokens, cfg)
    rel = float(jnp.max(jnp.abs(full - pre)) / jnp.max(jnp.abs(full)))
    assert rel < 5e-3, rel


def test_cim_enabled_lm_trains():
    """The paper's technique as a first-class LM feature: a CIM-quantized
    qwen3 block trains without NaNs."""
    from repro.core.cim_linear import CIMConfig
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32)
    cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    run = RunConfig(lr=1e-3, total_steps=5, warmup_steps=1)
    init_state, train_step = make_train_step(model, cfg, run)
    opt_state = init_state(params)
    batch = _batch(cfg)
    step = jax.jit(train_step)
    losses = []
    for _ in range(3):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses


def test_zamba2_hybrid_forward_shapes_and_dtypes():
    """zamba2 hybrid: mamba2 scan blocks + shared attention. Forward
    logits and block-level outputs carry the compute dtype; the mamba
    layer stack is genuinely stacked (leading layer axis)."""
    from repro.models import zamba2
    from repro.models.layers import cdt
    from repro.models.mamba2 import apply_mamba2, mamba2_specs
    cfg = get_config("zamba2-2.7b", reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    # stacked scan weights: leading axis = n_layers on every mamba leaf
    w_in = params["mamba_layers"]["in_proj"]["w"]
    assert w_in.ndim == 3 and w_in.shape[0] == cfg.n_layers
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    logits = model.forward(params, tokens, cfg)
    assert logits.shape == (B, T, cfg.vocab)
    assert logits.dtype == cdt(cfg)
    # one mamba2 block standalone: shape-preserving, compute dtype out
    bp = jax.tree.map(lambda a: a[0], params["mamba_layers"])
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model)
                          ).astype(cdt(cfg))
    y, st = apply_mamba2(bp, x, cfg, state=None)
    assert y.shape == x.shape and y.dtype == cdt(cfg) and st is None
    # decode cache dtypes: ssd/conv states are float32 accumulators
    cache = model.init_cache(cfg, B, T)
    assert cache["mamba"]["ssd"].dtype == jnp.float32
    assert cache["mamba"]["conv"].dtype == jnp.float32
    lg, cache2 = model.decode_step(params, cache, tokens[:, :1], cfg)
    assert lg.shape == (B, 1, cfg.vocab)
    assert jax.tree.all(jax.tree.map(
        lambda a, b_: a.shape == b_.shape and a.dtype == b_.dtype,
        cache, cache2))


def test_xlstm_block_shapes_and_dtypes():
    """mLSTM and sLSTM blocks: shape-preserving residual blocks emitting
    the compute dtype, with float32 recurrent states matching init_cache."""
    from repro.models import xlstm
    from repro.models.layers import cdt
    cfg = get_config("xlstm-1.3b", reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model)
                          ).astype(cdt(cfg))
    cache = model.init_cache(cfg, B, T)

    mp = jax.tree.map(lambda a: a[0], params["mlstm_layers"])
    mst = jax.tree.map(lambda a: a[0], cache["mlstm"])
    y, new_mst = xlstm.apply_mlstm(mp, x, cfg, state=mst)
    assert y.shape == x.shape and y.dtype == cdt(cfg)
    for a, b_ in zip(jax.tree.leaves(mst), jax.tree.leaves(new_mst)):
        assert a.shape == b_.shape and b_.dtype == jnp.float32

    sp = jax.tree.map(lambda a: a[0], params["slstm_layers"])
    sst = jax.tree.map(lambda a: a[0], cache["slstm"])
    y2, new_sst = xlstm.apply_slstm(sp, x, cfg, state=sst)
    assert y2.shape == x.shape and y2.dtype == cdt(cfg)
    for a, b_ in zip(jax.tree.leaves(sst), jax.tree.leaves(new_sst)):
        assert a.shape == b_.shape and b_.dtype == jnp.float32

    logits = model.forward(params, jax.random.randint(
        jax.random.PRNGKey(1), (B, T), 0, cfg.vocab), cfg)
    assert logits.shape == (B, T, cfg.vocab) and logits.dtype == cdt(cfg)


def test_moe_routing_load_and_dropless_small():
    from repro.models.layers import apply_moe, moe_specs
    cfg = get_config("moonshot-v1-16b-a3b", reduced=True).replace(
        compute_dtype="float32")
    sp = moe_specs(cfg)
    from repro.nn import init_params as ip
    p = ip(sp, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y = apply_moe(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
