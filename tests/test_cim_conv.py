"""CIM convolution framework (paper §III-C): group-conv tiling vs the
naive per-array loop, quantization behaviour, dequant-overhead accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import calibrate_conv as calibrate_cim_conv
from repro.api import conv2d as cim_conv2d
from repro.api import init_conv as init_cim_conv
from repro.core import (CIMConfig, Granularity, conv_dequant_muls,
                        conv_tiling)
from repro.core.bitsplit import place_values, split_digits
from repro.core.cim_conv import _quantize_conv_weight_int
from repro.core.cim_linear import _quantize_act


def _cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=6, array_rows=64, array_cols=64,
                act_signed=False)
    base.update(kw)
    return CIMConfig(**base)


def test_group_conv_equals_per_array_loop():
    """The paper's group-convolution trick must produce exactly the same
    per-array partial sums as sequentially convolving each channel slice
    (the 'sequential array indexing' it eliminates)."""
    cfg = _cfg(psum_quant=False)
    kh = kw_ = 3
    c_in, c_out, b = 19, 10, 2
    key = jax.random.PRNGKey(0)
    p = init_cim_conv(key, kh, kw_, c_in, c_out, cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (b, 8, 8, c_in)))
    p = calibrate_cim_conv(x, p, cfg)

    y_framework = cim_conv2d(x, p, cfg, compute_dtype=jnp.float32)

    # naive reference: quantize identically, loop arrays sequentially
    t, cpa = conv_tiling(kh, kw_, c_in, c_out, cfg.array_rows, cfg.array_cols,
                         cfg.weight_bits, cfg.cell_bits)
    a_int, s_a = _quantize_act(x, p, cfg)
    w_int = _quantize_conv_weight_int(p, cfg, t, cpa, kh, kw_, c_in, c_out)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)
    places = place_values(cfg.weight_bits, cfg.cell_bits)
    s_w = t.broadcast_weight_scale(p["s_w"])
    y_ref = 0.0
    for ti in range(t.k_tiles):
        lo, hi = ti * cpa, min((ti + 1) * cpa, c_in)
        for s in range(digits.shape[0]):
            psum = jax.lax.conv_general_dilated(
                a_int[..., lo:hi].astype(jnp.float32),
                digits[s, :, :, lo:hi, :].astype(jnp.float32),
                (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            y_ref += psum * places[s] * s_w[ti][None, None, None, :]
    y_ref = y_ref * jnp.maximum(s_a, 1e-9)
    np.testing.assert_allclose(np.asarray(y_framework), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_conv_tiling_keeps_kernels_intact():
    t, cpa = conv_tiling(3, 3, 64, 32, 128, 128, 4, 2)
    # an array holds whole stretched kernels: rows used = cpa * 9 <= 128
    assert cpa == 14 and t.array_rows == 126
    assert t.k_tiles == int(np.ceil(64 / 14))


def test_dequant_overhead_paper_fig8_ordering():
    """col/col costs the same as layer/col and more than layer/array."""
    t, _ = conv_tiling(3, 3, 64, 64, 128, 128, 4, 2)
    ll = t.dequant_muls(Granularity.LAYER, Granularity.LAYER)
    la = t.dequant_muls(Granularity.LAYER, Granularity.ARRAY)
    lc = t.dequant_muls(Granularity.LAYER, Granularity.COLUMN)
    cc = t.dequant_muls(Granularity.COLUMN, Granularity.COLUMN)
    ca = t.dequant_muls(Granularity.COLUMN, Granularity.ARRAY)
    assert ll == 1
    assert ll < la < lc
    assert cc == lc                    # the paper's zero-extra-overhead claim
    assert ca == lc                    # finest granularity dominates


@pytest.mark.parametrize("stride", [1, 2])
def test_conv_stride_and_shapes(stride):
    cfg = _cfg()
    p = init_cim_conv(jax.random.PRNGKey(0), 3, 3, 8, 12, cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 8)))
    p = calibrate_cim_conv(x, p, cfg, stride=stride)
    y = cim_conv2d(x, p, cfg, stride=stride, compute_dtype=jnp.float32)
    assert y.shape == (2, 8 // stride, 8 // stride, 12)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_conv_grads_flow():
    cfg = _cfg()
    p = init_cim_conv(jax.random.PRNGKey(0), 3, 3, 8, 12, cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 8)))
    p = calibrate_cim_conv(x, p, cfg)

    def loss(p):
        return jnp.sum(cim_conv2d(x, p, cfg, compute_dtype=jnp.float32) ** 2)
    g = jax.grad(loss)(p)
    for name in ("w", "s_w", "s_p", "s_a"):
        gn = float(jnp.linalg.norm(g[name]))
        assert np.isfinite(gn) and gn > 0, name


def test_1x1_conv():
    cfg = _cfg(array_rows=16)
    p = init_cim_conv(jax.random.PRNGKey(0), 1, 1, 24, 8, cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 4, 4, 24)))
    p = calibrate_cim_conv(x, p, cfg)
    y = cim_conv2d(x, p, cfg, compute_dtype=jnp.float32)
    assert y.shape == (2, 4, 4, 8) and bool(jnp.all(jnp.isfinite(y)))
