"""Sharding plumbing: logical-axis resolution, spec trees, cell builder on
a host mesh (no 512-device requirement in unit tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.nn.module import (ParamSpec, eval_shape_params, init_params,
                             resolve_pspec, stack_specs)


def test_resolve_pspec_basic():
    rules = {"vocab": "model", "embed": ("pod", "data"), "heads": "model"}
    assert resolve_pspec(("vocab", "embed"), rules) == \
        P("model", ("pod", "data"))
    assert resolve_pspec((None, "heads"), rules) == P(None, "model")
    assert resolve_pspec(None, rules) == P()


def test_resolve_pspec_drops_duplicate_mesh_axes():
    rules = {"embed": "model", "mlp": "model"}
    # 'model' may appear once; second use degrades to None
    assert resolve_pspec(("embed", "mlp"), rules) == P("model")


def test_resolve_pspec_trailing_nones_trimmed():
    rules = {"vocab": "model"}
    sp = resolve_pspec(("vocab", "embed", None), rules)
    assert sp == P("model")


def test_stack_specs_shapes_and_init():
    sp = {"w": ParamSpec((4, 8), jnp.float32, "normal:0.1", ("embed", "mlp"))}
    st = stack_specs(sp, 3)
    assert st["w"].shape == (3, 4, 8)
    assert st["w"].pspec == (None, "embed", "mlp")
    params = init_params(st, jax.random.PRNGKey(0))
    assert params["w"].shape == (3, 4, 8)
    # layers get distinct init
    assert not np.allclose(np.asarray(params["w"][0]),
                           np.asarray(params["w"][1]))


def test_eval_shape_params_no_alloc():
    sp = {"big": ParamSpec((1 << 14, 1 << 14), jnp.float32, "zeros", None)}
    st = eval_shape_params(sp)
    assert st["big"].shape == (1 << 14, 1 << 14)
    assert isinstance(st["big"], jax.ShapeDtypeStruct)


def test_init_params_path_stability():
    """Adding a parameter must not change other leaves' values."""
    sp1 = {"a": ParamSpec((4,), jnp.float32, "normal:1.0", None)}
    sp2 = {"a": ParamSpec((4,), jnp.float32, "normal:1.0", None),
           "b": ParamSpec((4,), jnp.float32, "normal:1.0", None)}
    key = jax.random.PRNGKey(42)
    p1 = init_params(sp1, key)
    p2 = init_params(sp2, key)
    np.testing.assert_array_equal(np.asarray(p1["a"]), np.asarray(p2["a"]))


def test_build_cell_on_host_mesh_lowers():
    """A reduced cell lowers + compiles on the single-device host mesh —
    the same path the production dry-run takes at 512 devices."""
    from repro.launch.cells import build_cell
    from repro.configs.base import SHAPES
    import repro.configs.base as base
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # use a tiny custom shape to keep the host compile fast
    SHAPES["_tiny_train"] = base.Shape("_tiny_train", "train", 32, 4)
    try:
        cell = build_cell("qwen3-0.6b", "_tiny_train", mesh, reduced=True,
                          accum=2)
        compiled = cell.lower().compile()
        assert compiled.cost_analysis() is not None
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes >= 0
    finally:
        del SHAPES["_tiny_train"]


def test_build_decode_cell_on_host_mesh():
    from repro.launch.cells import build_cell
    import repro.configs.base as base
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    base.SHAPES["_tiny_decode"] = base.Shape("_tiny_decode", "decode", 64, 2)
    try:
        cell = build_cell("llama3-8b", "_tiny_decode", mesh, reduced=True)
        compiled = cell.lower().compile()
        assert compiled is not None
    finally:
        del base.SHAPES["_tiny_decode"]
