"""Serving engine: batched generation correctness and slot bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.registry import get_model
from repro.nn import init_params
from repro.serve.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b", reduced=True).replace(
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    return cfg, model, params


def test_generate_batch_matches_stepwise_argmax(setup):
    cfg, model, params = setup
    B, Tp, Tn = 2, 8, 6
    eng = ServingEngine(model, cfg, params, batch_size=B, max_len=64)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (B, Tp),
                                            0, cfg.vocab), np.int32)
    out = eng.generate_batch(prompts, Tn)
    assert out.shape == (B, Tn)

    # oracle: full forward re-scoring at every step
    seq = jnp.asarray(prompts)
    for t in range(Tn):
        logits = model.forward(params, seq, cfg)
        nxt = jnp.argmax(logits[:, -1, :].astype(jnp.float32), axis=-1)
        assert np.array_equal(np.asarray(nxt), out[:, t]), f"step {t}"
        seq = jnp.concatenate([seq, nxt[:, None].astype(jnp.int32)], axis=1)


def test_engine_slots_retire_and_refill(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, cfg, params, batch_size=2, max_len=64)
    r1 = eng.submit([3, 5, 7], max_new_tokens=4)
    r2 = eng.submit([11, 13], max_new_tokens=2)
    r3 = eng.submit([2], max_new_tokens=3)
    done = {}
    for _ in range(30):
        for fin in eng.step():
            done[fin["rid"]] = fin["tokens"]
        if len(done) == 3:
            break
    assert set(done) == {r1, r2, r3}
    assert len(done[r1]) == 4 and len(done[r2]) == 2 and len(done[r3]) == 3


def test_temperature_sampling_runs(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, cfg, params, batch_size=2, max_len=32,
                        temperature=1.0)
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate_batch(prompts, 5)
    assert out.shape == (2, 5)
    assert out.min() >= 0 and out.max() < cfg.vocab


def test_engine_from_artifact_serves_deploy_backend(setup, tmp_path):
    """Pack the LM with pack_model, save/load a DeployArtifact, and serve
    it on the deploy backend; greedy tokens must match the emulate path
    when the CIM numerics are the bottleneck-free f32 configuration."""
    import dataclasses

    from repro.api import model_artifact
    from repro.core.cim_linear import CIMConfig
    from repro.serve.engine import engine_from_artifact

    cfg, model, _ = setup
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32)
    qcfg = dataclasses.replace(cfg, cim=cim)
    qmodel = get_model(qcfg)
    qparams = init_params(qmodel.specs(qcfg), jax.random.PRNGKey(0))

    art = model_artifact(qparams, cim, meta={"arch": "qwen3-0.6b-reduced"})
    art.save(str(tmp_path))

    eng = engine_from_artifact(str(tmp_path), qcfg, batch_size=2, max_len=32)
    assert eng.cfg.cim.mode == "deploy"
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (2, 4),
                                            0, qcfg.vocab), np.int32)
    out_deploy = eng.generate_batch(prompts, 3)

    eng_e = ServingEngine(qmodel, qcfg, qparams, batch_size=2, max_len=32)
    out_emulate = eng_e.generate_batch(prompts, 3)
    assert np.array_equal(out_deploy, out_emulate)
