"""Deploy-vs-emulate equivalence under cell variation (DESIGN.md §8).

The contract: noise is drawn in the packed digit-plane layout on both
paths, so identical (variation_key, variation_std) must give bit-exact
(1e-4 in f32, same as the noise-free contract) outputs across linear and
conv, strides/paddings, int8/int4 packing — and sigma=0/None must take
the no-op fast path. Plus the statistical property the Monte-Carlo
harness rests on: psum error grows monotonically with sigma.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import calibrate_conv as calibrate_cim_conv
from repro.api import calibrate_linear as calibrate_cim
from repro.api import conv2d as cim_conv2d
from repro.api import init_conv as init_cim_conv
from repro.api import init_linear as init_cim_linear
from repro.api import linear as cim_linear
from repro.api import pack_conv as pack_deploy_conv
from repro.api import pack_linear as pack_deploy
from repro.api import pack_model
from repro.core import CIMConfig, Granularity, perturb_packed
from repro.core.variation import variation_wanted
from repro.eval import robustness


def _lin_cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=4, array_rows=32, array_cols=32)
    base.update(kw)
    return CIMConfig(**base)


def _lin_setup(cfg, k=70, n=24, b=8, seed=0):
    p = init_cim_linear(jax.random.PRNGKey(seed), k, n, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, k)) * 0.5
    return calibrate_cim(x, p, cfg), x


def _conv_cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=6, array_rows=64, array_cols=64,
                act_signed=False)
    base.update(kw)
    return CIMConfig(**base)


def _conv_setup(cfg, kh=3, c_in=19, c_out=10, b=2, hw=8, stride=1,
                padding="SAME", seed=0):
    p = init_cim_conv(jax.random.PRNGKey(seed), kh, kh, c_in, c_out, cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (b, hw, hw, c_in)))
    return calibrate_cim_conv(x, p, cfg, stride=stride, padding=padding), x


# ---------------------------------------------------------------------------
# bit-exactness under a shared key
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wb_cb", [(4, 2), (3, 1)])
@pytest.mark.parametrize("sigma", [0.1, 0.3])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_linear_deploy_matches_emulate_under_variation(wb_cb, sigma,
                                                       use_kernel):
    wb, cb = wb_cb
    cfg = _lin_cfg(weight_bits=wb, cell_bits=cb)
    p, x = _lin_setup(cfg)
    vk = jax.random.PRNGKey(42)
    y_em = cim_linear(x, p, cfg, variation_key=vk, variation_std=sigma,
                      compute_dtype=jnp.float32)
    pd = pack_deploy(p, cfg)
    y_dep = cim_linear(x, pd, cfg.replace(mode="deploy",
                                          use_kernel=use_kernel),
                       variation_key=vk, variation_std=sigma,
                       compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_dep), np.asarray(y_em),
                               rtol=1e-4, atol=1e-4)
    # and the noise actually did something
    y_clean = cim_linear(x, p, cfg, compute_dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(y_em - y_clean))) > 0


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "VALID")])
@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_conv_deploy_matches_emulate_under_variation(stride, padding,
                                                     pack_dtype):
    cfg = _conv_cfg(pack_dtype=pack_dtype)
    p, x = _conv_setup(cfg, stride=stride, padding=padding)
    vk = jax.random.PRNGKey(7)
    y_em = cim_conv2d(x, p, cfg, stride=stride, padding=padding,
                      variation_key=vk, variation_std=0.2,
                      compute_dtype=jnp.float32)
    dp = pack_deploy_conv(p, cfg)
    y_dep = cim_conv2d(x, dp, cfg.replace(mode="deploy"), stride=stride,
                       padding=padding, variation_key=vk, variation_std=0.2,
                       compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_dep), np.asarray(y_em),
                               rtol=1e-4, atol=1e-4)


def test_variation_std_falls_back_to_cfg():
    """The cfg knob and the argument override are the same scenario axis."""
    cfg = _conv_cfg(variation_std=0.2)
    p, x = _conv_setup(cfg)
    vk = jax.random.PRNGKey(3)
    y_cfg = cim_conv2d(x, p, cfg, variation_key=vk,
                       compute_dtype=jnp.float32)
    y_arg = cim_conv2d(x, p, cfg.replace(variation_std=0.0),
                       variation_key=vk, variation_std=0.2,
                       compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_cfg), np.asarray(y_arg))


def test_sigma_zero_is_noop_fast_path():
    """Static sigma<=0 (or key=None) must skip noise entirely — outputs
    bitwise equal to the clean forward, int planes untouched."""
    assert not variation_wanted(jax.random.PRNGKey(0), 0.0)
    assert not variation_wanted(jax.random.PRNGKey(0), None)
    assert not variation_wanted(None, 0.5)
    assert variation_wanted(jax.random.PRNGKey(0), 0.5)

    cfg = _conv_cfg()
    p, x = _conv_setup(cfg)
    dp = pack_deploy_conv(p, cfg)
    dcfg = cfg.replace(mode="deploy")
    y_clean = cim_conv2d(x, dp, dcfg, compute_dtype=jnp.float32)
    y_zero = cim_conv2d(x, dp, dcfg, variation_key=jax.random.PRNGKey(5),
                        variation_std=0.0, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_clean), np.asarray(y_zero))


def test_perturb_packed_baked_equals_lazy():
    """pack-time baked noise == forward-time lazy noise from the same key
    (the 'carry' and 'lazily materialize' options are one realization)."""
    cfg = _conv_cfg()
    p, x = _conv_setup(cfg)
    dp = pack_deploy_conv(p, cfg)
    dcfg = cfg.replace(mode="deploy")
    vk = jax.random.PRNGKey(11)
    y_lazy = cim_conv2d(x, dp, dcfg, variation_key=vk, variation_std=0.2,
                        compute_dtype=jnp.float32)
    baked = perturb_packed(dp, vk, 0.2)
    assert baked["w_digits"].dtype == jnp.float32
    y_baked = cim_conv2d(x, baked, dcfg, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_lazy), np.asarray(y_baked))
    # pack-time baking is the same op
    dp2 = pack_deploy_conv(p, cfg, variation_key=vk, variation_std=0.2)
    np.testing.assert_array_equal(np.asarray(dp2["w_digits"]),
                                  np.asarray(baked["w_digits"]))


def test_perturb_packed_sample_folding():
    cfg = _lin_cfg()
    p, _ = _lin_setup(cfg)
    pd = pack_deploy(p, cfg)
    key = jax.random.PRNGKey(0)
    a = perturb_packed(pd, key, 0.2, sample=0)["w_digits"]
    b = perturb_packed(pd, key, 0.2, sample=1)["w_digits"]
    c = perturb_packed(pd, jax.random.fold_in(key, 1), 0.2)["w_digits"]
    assert float(jnp.max(jnp.abs(a - b))) > 0
    np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


# ---------------------------------------------------------------------------
# whole-model and statistical properties
# ---------------------------------------------------------------------------

def test_resnet_deploy_matches_emulate_under_variation():
    from repro.models import resnet
    cim = _conv_cfg()
    cfg = resnet.ResNetConfig(name="tiny", depth=20, n_classes=10,
                              widths=(8, 16), in_hw=8, cim=cim)
    params, state = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    params = resnet.calibrate(params, state, x, cfg)
    vk = jax.random.PRNGKey(21)
    y_e, _ = resnet.forward(params, state, x, cfg, train=False,
                            variation_key=vk, variation_std=0.15)
    dp = pack_model(params, cfg.cim)
    dcfg = dataclasses.replace(cfg, cim=cim.replace(mode="deploy"))
    y_d, _ = resnet.forward(dp, state, x, dcfg, train=False,
                            variation_key=vk, variation_std=0.15)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=1e-4, atol=1e-4)


def test_resnet_variation_keys_match_forward_order():
    from repro.models import resnet
    cim = _conv_cfg()
    cfg = resnet.ResNetConfig(name="tiny", depth=20, n_classes=10,
                              widths=(8, 16), in_hw=8, cim=cim)
    names = [n for n, _ in resnet.conv_layer_names(cfg)]
    assert names[0] == "s0b0.conv1" and "s1b0.proj" in names
    keys = resnet.variation_keys(jax.random.PRNGKey(0), cfg)
    assert set(keys) == set(names)
    assert resnet.variation_keys(None, cfg) is None
    # taps cover exactly the conv layers, with the right spatial dims
    params, state = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    _, _, taps = resnet.forward(params, state, x, cfg, train=False,
                                return_taps=True)
    assert set(taps) == set(names)


def test_mc_psum_error_grows_monotonically_with_sigma():
    """Statistical contract of the Monte-Carlo harness: mean relative
    deploy-output error increases with sigma (common random numbers
    across sigma levels make this deterministic in practice)."""
    cfg = _lin_cfg(array_rows=64, psum_bits=8, act_bits=8)
    p, x = _lin_setup(cfg, k=64, n=16, b=32)
    pd = pack_deploy(p, cfg)
    sigmas = (0.05, 0.1, 0.2, 0.4)
    errs = robustness.monte_carlo_linear_error(
        pd, cfg, x, key=jax.random.PRNGKey(0), sigmas=sigmas, n_samples=6)
    assert errs.shape == (len(sigmas), 6)
    mean = errs.mean(axis=1)
    assert np.all(mean > 0)
    assert np.all(np.diff(mean) > 0), mean


def test_per_layer_attribution_runs_on_deploy():
    from repro.models import resnet
    cim = _conv_cfg()
    cfg = resnet.ResNetConfig(name="tiny", depth=20, n_classes=10,
                              widths=(8, 16), in_hw=8, cim=cim)
    params, state = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    params = resnet.calibrate(params, state, x, cfg)
    dp = pack_model(params, cfg.cim)
    dcfg = dataclasses.replace(cfg, cim=cim.replace(mode="deploy"))
    attrib = robustness.per_layer_attribution(
        dp, state, dcfg, x, key=jax.random.PRNGKey(2), sigma=0.3)
    names = [n for n, _ in resnet.conv_layer_names(cfg)]
    assert [a.name for a in attrib] == names
    for a in attrib:
        assert np.isfinite(a.rel_err) and a.rel_err > 0
        assert a.col_err.shape[0] in (8, 16)
        assert 0 <= a.worst_col < a.col_err.shape[0]
