"""Unified lifecycle API contracts: typed handles, backend registry,
versioned DeployArtifact save/load round-trips (bit-exact), and the
deprecation shims over the pre-API entry points."""
import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import (Backend, DeployArtifact, QuantConv2d, QuantLinear,
                       Variation, get_backend, model_artifact, pack_model,
                       register_backend, registered_backends)
from repro.core import CIMConfig, Granularity


def _cfg(**kw):
    base = dict(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=4, array_rows=32, array_cols=32)
    base.update(kw)
    return CIMConfig(**base)


def _linear_handle(cfg, k=96, n=40, batch=8, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, k)) * 0.5
    h = QuantLinear(k, n, cfg).init(key).calibrate(x)
    return h, x


def _conv_handle(cfg, stride=1, padding="SAME", c_in=12, c_out=20, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (2, 10, 10, c_in)))
    h = QuantConv2d(3, 3, c_in, c_out, cfg, stride=stride,
                    padding=padding).init(key).calibrate(x)
    return h, x


def _assert_tree_bit_exact(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# artifact save -> load -> bit-exact forward round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_linear_artifact_roundtrip_bit_exact(tmp_path, pack_dtype):
    h, x = _linear_handle(_cfg(pack_dtype=pack_dtype))
    art = h.pack()
    art.save(str(tmp_path))
    loaded = DeployArtifact.load(str(tmp_path))
    assert loaded.layout_version == art.layout_version
    assert loaded.config == art.config
    assert get_backend(loaded.config.mode).packed
    _assert_tree_bit_exact(art.params, loaded.params)
    y_mem = QuantLinear.from_artifact(art)(x)
    y_disk = QuantLinear.from_artifact(loaded)(x)
    np.testing.assert_array_equal(np.asarray(y_mem), np.asarray(y_disk))


@pytest.mark.parametrize("pack_dtype,stride,padding", [
    ("int8", 1, "SAME"), ("int8", 2, "VALID"), ("int4", 2, "SAME")])
def test_conv_artifact_roundtrip_bit_exact(tmp_path, pack_dtype, stride,
                                           padding):
    h, x = _conv_handle(_cfg(act_signed=False, pack_dtype=pack_dtype),
                        stride=stride, padding=padding)
    art = h.pack()
    art.save(str(tmp_path))
    loaded = DeployArtifact.load(str(tmp_path))
    if pack_dtype == "int4":
        assert str(np.asarray(loaded.params["w_digits"]).dtype) == "int4"
    _assert_tree_bit_exact(art.params, loaded.params)
    served = QuantConv2d.from_artifact(loaded)
    assert (served.stride, served.padding) == (stride, padding)
    y_mem = QuantConv2d.from_artifact(art)(x)
    np.testing.assert_array_equal(np.asarray(y_mem), np.asarray(served(x)))


@pytest.mark.parametrize("kind", ["linear", "conv"])
def test_variation_baked_pack_roundtrip(tmp_path, kind):
    vkey = jax.random.PRNGKey(7)
    if kind == "linear":
        h, x = _linear_handle(_cfg())
        cls = QuantLinear
    else:
        h, x = _conv_handle(_cfg(act_signed=False))
        cls = QuantConv2d
    art = h.pack(variation=Variation(vkey, 0.25))
    clean = h.pack()
    # baking really perturbed the planes (float realization)
    assert np.asarray(art.params["w_digits"]).dtype == np.float32
    assert not np.array_equal(np.asarray(art.params["w_digits"]),
                              np.asarray(clean.params["w_digits"]))
    art.save(str(tmp_path))
    loaded = DeployArtifact.load(str(tmp_path))
    _assert_tree_bit_exact(art.params, loaded.params)
    np.testing.assert_array_equal(np.asarray(cls.from_artifact(art)(x)),
                                  np.asarray(cls.from_artifact(loaded)(x)))


def test_model_artifact_roundtrip_resnet(tmp_path):
    from repro.models import resnet
    cim = _cfg(act_signed=False)
    cfg = resnet.ResNetConfig(name="tiny", depth=20, n_classes=10,
                              widths=(8, 16), in_hw=8, cim=cim)
    params, state = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    params = resnet.calibrate(params, state, x, cfg)
    art = model_artifact(params, cim, meta={"arch": "resnet20-tiny"})
    assert art.kind == "model"
    art.save(str(tmp_path))
    loaded = DeployArtifact.load(str(tmp_path))
    assert loaded.meta["arch"] == "resnet20-tiny"
    _assert_tree_bit_exact(art.params, loaded.params)
    dcfg = dataclasses.replace(cfg, cim=loaded.config)
    y_mem, _ = resnet.forward(art.params, state, x, dcfg, train=False)
    y_disk, _ = resnet.forward(loaded.params, state, x, dcfg, train=False)
    np.testing.assert_array_equal(np.asarray(y_mem), np.asarray(y_disk))
    # the fp stem / fc / bn passed through the pack untouched
    _assert_tree_bit_exact(art.params["stem"], params["stem"])
    _assert_tree_bit_exact(art.params["fc"], params["fc"])


def test_pack_model_recurses_into_list_nodes(tmp_path):
    """Trees rebuilt by checkpoint.restore_tree may contain list nodes;
    CIM layers inside them must be packed, not silently passed through.
    Tuple nodes are normalized to lists so the in-memory pack and a
    loaded artifact are STRUCTURE-exact, not just leaf-exact."""
    cfg = _cfg()
    h, x = _linear_handle(cfg)
    tree = {"blocks": ({"fc": h.params}, {"fc": h.params}), "bias": x[:1]}
    packed = pack_model(tree, cfg)
    assert isinstance(packed["blocks"], list)
    for blk in packed["blocks"]:
        assert "w_digits" in blk["fc"] and "w" not in blk["fc"]
    _assert_tree_bit_exact(packed["blocks"][0]["fc"],
                           api.pack_linear(h.params, cfg))
    art = model_artifact(tree, cfg)
    art.save(str(tmp_path))
    loaded = DeployArtifact.load(str(tmp_path))
    assert (jax.tree.structure(art.params)
            == jax.tree.structure(loaded.params))
    _assert_tree_bit_exact(art.params, loaded.params)


def test_pack_model_carries_extra_layer_keys():
    """A CIM-layer node's non-quartet keys (e.g. a bias) must survive
    packing, for both the flat and the stacked (vmapped) paths."""
    cfg = _cfg()
    h, _ = _linear_handle(cfg)
    bias = jnp.arange(h.n, dtype=jnp.float32)
    packed = pack_model({"fc": {**h.params, "b": bias}}, cfg)
    assert "w_digits" in packed["fc"]
    np.testing.assert_array_equal(np.asarray(packed["fc"]["b"]),
                                  np.asarray(bias))
    stacked = jax.tree.map(lambda a: jnp.stack([a, a]), h.params)
    sb = jnp.stack([bias, bias])
    packed = pack_model({"fc": {**stacked, "b": sb}}, cfg)
    assert packed["fc"]["w_digits"].shape[0] == 2
    np.testing.assert_array_equal(np.asarray(packed["fc"]["b"]),
                                  np.asarray(sb))


def test_artifact_overwrite_never_pairs_new_params_with_stale_header(
        tmp_path):
    """Re-saving into an existing artifact dir removes the stale header
    before the new params land, so a mid-overwrite crash yields a loudly
    incomplete artifact rather than a silent config/params mismatch."""
    h, x = _linear_handle(_cfg())
    h.pack().save(str(tmp_path))
    h4 = QuantLinear(h.k, h.n, h.cfg.replace(pack_dtype="int4"),
                     params=h.params)
    h4.pack().save(str(tmp_path))
    loaded = DeployArtifact.load(str(tmp_path))
    assert loaded.config.pack_dtype == "int4"
    # int4 linear planes with an even row count store nibble-packed (v4)
    assert str(np.asarray(loaded.params["w_digits"]).dtype) == "uint8"
    np.testing.assert_array_equal(
        np.asarray(QuantLinear.from_artifact(loaded)(x)),
        np.asarray(QuantLinear.from_artifact(h4.pack())(x)))


def test_restore_tree_non_dict_roots(tmp_path):
    from repro.checkpoint import restore_tree, save
    save(str(tmp_path / "lst"), 0, [np.ones((2,), np.float32),
                                    np.zeros((3,), np.float32)])
    out = restore_tree(str(tmp_path / "lst"), step=0)
    assert isinstance(out, list) and len(out) == 2
    save(str(tmp_path / "leaf"), 0, np.arange(4, dtype=np.int32))
    leaf = restore_tree(str(tmp_path / "leaf"), step=0)
    np.testing.assert_array_equal(leaf, np.arange(4, dtype=np.int32))


def test_restore_tree_keeps_dunder_keyed_dicts(tmp_path):
    from repro.checkpoint import restore_tree, save
    tree = {"x": {"__tag": np.ones((2,), np.float32)}}
    save(str(tmp_path), 0, tree)
    out = restore_tree(str(tmp_path), step=0)
    assert isinstance(out["x"], dict) and "__tag" in out["x"]
    _assert_tree_bit_exact(tree, out)
    # numeric '__<i>' dict keys collide with the list encoding and are
    # rejected loudly at save time instead of corrupting restore_tree
    with pytest.raises(ValueError, match="reserved list encoding"):
        save(str(tmp_path), 1, {"y": {"__0": np.ones((1,), np.float32)}})


def test_artifact_version_gate(tmp_path):
    h, _ = _linear_handle(_cfg())
    h.pack().save(str(tmp_path))
    jpath = tmp_path / "artifact.json"
    head = json.loads(jpath.read_text())
    head["layout_version"] = api.ARTIFACT_LAYOUT_VERSION + 1
    jpath.write_text(json.dumps(head))
    with pytest.raises(ValueError, match="layout_version"):
        DeployArtifact.load(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        DeployArtifact.load(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# backend registry dispatch
# ---------------------------------------------------------------------------

def test_backend_equivalence_linear():
    h, x = _linear_handle(_cfg())
    y_em = h(x)
    served = QuantLinear.from_artifact(h.pack())
    y_deploy = served(x)
    y_ref = served.with_backend("ref")(x)
    np.testing.assert_allclose(np.asarray(y_em), np.asarray(y_deploy),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_deploy), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)


def test_backend_equivalence_conv():
    h, x = _conv_handle(_cfg(act_signed=False), stride=2)
    y_em = h(x)
    served = QuantConv2d.from_artifact(h.pack())
    y_deploy = served(x)
    y_ref = served.with_backend("ref")(x)
    np.testing.assert_allclose(np.asarray(y_em), np.asarray(y_deploy),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_deploy), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)


def test_builtin_backends_registered():
    assert set(registered_backends()) >= {"off", "emulate", "deploy", "ref"}
    assert not get_backend("emulate").packed
    assert get_backend("deploy").packed and get_backend("ref").packed


def test_register_custom_backend_dispatches():
    deploy = get_backend("deploy")
    name = "test-doubling-deploy"
    if name not in registered_backends():
        register_backend(Backend(
            name=name,
            linear=lambda *a: 2.0 * deploy.linear(*a),
            conv=lambda *a: 2.0 * deploy.conv(*a),
            packed=True, description="test backend"))
    with pytest.raises(ValueError, match="already registered"):
        register_backend(get_backend(name))
    h, x = _linear_handle(_cfg())
    served = QuantLinear.from_artifact(h.pack())
    np.testing.assert_allclose(np.asarray(served.with_backend(name)(x)),
                               2.0 * np.asarray(served(x)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# config validation (fail loudly at construction)
# ---------------------------------------------------------------------------

def test_unknown_mode_raises_at_construction():
    with pytest.raises(ValueError, match="unknown CIM mode"):
        CIMConfig(mode="depoly")
    with pytest.raises(ValueError, match="unknown CIM mode"):
        _cfg().replace(mode="deplyo")


def test_replace_rejects_unknown_fields():
    with pytest.raises(TypeError, match="unknown field"):
        _cfg().replace(weight_bit=3)


def test_unknown_granularity_and_pack_dtype_raise():
    with pytest.raises(ValueError, match="weight_granularity"):
        CIMConfig(weight_granularity="colum")
    with pytest.raises(ValueError, match="pack_dtype"):
        CIMConfig(pack_dtype="int2")
    # string granularities coerce to the enum
    assert (CIMConfig(weight_granularity="array").weight_granularity
            is Granularity.ARRAY)


def test_handle_guards():
    h = QuantLinear(8, 4, _cfg())
    with pytest.raises(ValueError, match="no params"):
        h(jnp.zeros((2, 8)))
    hc, _ = _conv_handle(_cfg(act_signed=False))
    with pytest.raises(ValueError, match="'linear' artifact"):
        QuantLinear.from_artifact(hc.pack())


def test_pack_and_calibrate_require_trainable_params():
    h, x = _linear_handle(_cfg())
    served = QuantLinear.from_artifact(h.pack())
    with pytest.raises(ValueError, match="packed digit"):
        served.pack()
    with pytest.raises(ValueError, match="packed digit"):
        served.calibrate(x)


def test_with_backend_checks_params_layout():
    h, x = _linear_handle(_cfg())
    served = QuantLinear.from_artifact(h.pack())
    with pytest.raises(ValueError, match="trainable float weights"):
        served.with_backend("emulate")
    with pytest.raises(ValueError, match="packed digit planes"):
        h.with_backend("deploy")
    assert h.with_backend("off")(x).shape == (x.shape[0], h.n)
    assert served.with_backend("ref")(x).shape == (x.shape[0], h.n)


# ---------------------------------------------------------------------------
# deprecation shims: still functional, and they warn
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings("error::DeprecationWarning")
def test_legacy_linear_shims_warn_and_match():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 48)) * 0.5
    from repro.core import (calibrate_cim, cim_linear, init_cim_linear,
                            pack_deploy)
    with pytest.warns(DeprecationWarning, match="init_cim_linear"):
        p_old = init_cim_linear(key, 48, 16, cfg)
    p_new = api.init_linear(key, 48, 16, cfg)
    _assert_tree_bit_exact(p_old, p_new)
    with pytest.warns(DeprecationWarning, match="calibrate_cim"):
        p_old = calibrate_cim(x, p_old, cfg)
    p_new = api.calibrate_linear(x, p_new, cfg)
    with pytest.warns(DeprecationWarning, match="cim_linear"):
        y_old = cim_linear(x, p_old, cfg, compute_dtype=jnp.float32)
    y_new = api.linear(x, p_new, cfg, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))
    with pytest.warns(DeprecationWarning, match="pack_deploy"):
        d_old = pack_deploy(p_old, cfg)
    _assert_tree_bit_exact(d_old, api.pack_linear(p_new, cfg))


@pytest.mark.filterwarnings("error::DeprecationWarning")
def test_legacy_conv_and_resnet_shims_warn_and_match():
    cfg = _cfg(act_signed=False)
    key = jax.random.PRNGKey(0)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 6)))
    from repro.core import (calibrate_cim_conv, cim_conv2d, init_cim_conv,
                            pack_deploy_conv)
    with pytest.warns(DeprecationWarning, match="init_cim_conv"):
        p_old = init_cim_conv(key, 3, 3, 6, 10, cfg)
    p_new = api.init_conv(key, 3, 3, 6, 10, cfg)
    _assert_tree_bit_exact(p_old, p_new)
    with pytest.warns(DeprecationWarning, match="calibrate_cim_conv"):
        p_old = calibrate_cim_conv(x, p_old, cfg)
    p_new = api.calibrate_conv(x, p_new, cfg)
    with pytest.warns(DeprecationWarning, match="cim_conv2d"):
        y_old = cim_conv2d(x, p_old, cfg, compute_dtype=jnp.float32)
    y_new = api.conv2d(x, p_new, cfg, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))
    with pytest.warns(DeprecationWarning, match="pack_deploy_conv"):
        d_old = pack_deploy_conv(p_old, cfg)
    _assert_tree_bit_exact(d_old, api.pack_conv(p_new, cfg))

    from repro.models import resnet
    rcfg = resnet.ResNetConfig(name="tiny", depth=20, n_classes=10,
                               widths=(8, 16), in_hw=8, cim=cfg)
    params, _ = resnet.init(jax.random.PRNGKey(2), rcfg)
    with pytest.warns(DeprecationWarning, match="pack_deploy"):
        legacy = resnet.pack_deploy(params, rcfg)
    _assert_tree_bit_exact(legacy, pack_model(params, cfg))


# ---------------------------------------------------------------------------
# template-free checkpoint restore (artifact substrate)
# ---------------------------------------------------------------------------

def test_restore_tree_rebuilds_structure(tmp_path):
    from repro.checkpoint import restore_tree, save
    tree = {"a": {"b": np.arange(6, dtype=np.int32).reshape(2, 3)},
            "c": [np.ones((2,), np.float32), np.zeros((1,), np.float32)],
            "d": np.asarray(jnp.bfloat16(1.5))}
    save(str(tmp_path), 3, tree)
    out = restore_tree(str(tmp_path), step=3)
    assert isinstance(out["c"], list) and len(out["c"]) == 2
    _assert_tree_bit_exact(tree, out)
