"""End-to-end system test: QAT a CIM-quantized LM on the synthetic stream,
checkpoint it, deploy-pack it, and serve — the full paper pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity
from repro.data.pipeline import make_lm_pipeline
from repro.models.registry import get_model
from repro.nn import init_params
from repro.runtime.fault_tolerance import FaultTolerantLoop, TrainLoopState
from repro.train.trainer import make_train_step


def test_end_to_end_cim_qat_checkpoint_serve(tmp_path):
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    weight_granularity=Granularity.COLUMN,
                    psum_granularity=Granularity.COLUMN)
    cfg = get_config("olmo-1b", reduced=True, cim=cim).replace(
        compute_dtype="float32")
    model = get_model(cfg)
    run = RunConfig(lr=2e-3, total_steps=30, warmup_steps=3)
    init_state, train_step = make_train_step(model, cfg, run)
    train_step = jax.jit(train_step)

    def fresh():
        params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
        return TrainLoopState(params, init_state(params), 0)

    def batches():
        pipe = make_lm_pipeline(vocab=cfg.vocab, seq_len=24, global_batch=4)
        for raw in pipe:
            yield {"tokens": jnp.asarray(raw["tokens"])}

    loop = FaultTolerantLoop(str(tmp_path), checkpoint_every=10,
                             async_save=False)
    losses = []
    st = loop.run(fresh(), train_step, batches(), total_steps=25,
                  log_every=1,
                  on_metrics=lambda s, m: losses.append(float(m["loss"])))
    assert st.step == 25 and loop.mgr.latest_step() == 25
    assert np.mean(losses[-3:]) < np.mean(losses[:3])  # QAT learns

    # restore and serve with the trained quantized model
    st2 = loop.resume_or_init(fresh)
    assert st2.step == 25
    from repro.serve.engine import ServingEngine
    eng = ServingEngine(model, cfg, st2.params, batch_size=2, max_len=64)
    out = eng.generate_batch(np.zeros((2, 4), np.int32), 5)
    assert out.shape == (2, 5) and out.min() >= 0
