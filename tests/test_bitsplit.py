"""Bit-split decomposition properties (paper Fig. 5)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.bitsplit import place_values, recombine, split_digits
from repro.core.granularity import n_splits


@settings(max_examples=60, deadline=None)
@given(
    wb_cb=st.sampled_from([(2, 1), (3, 1), (3, 2), (3, 3), (4, 2), (4, 1),
                           (4, 4), (8, 2), (8, 3), (6, 2)]),
    seed=st.integers(0, 2 ** 16),
)
def test_roundtrip_exact(wb_cb, seed):
    wb, cb = wb_cb
    rng = np.random.RandomState(seed)
    w = rng.randint(-(2 ** (wb - 1)), 2 ** (wb - 1), size=(13, 7)
                    ).astype(np.float32)
    d = split_digits(jnp.asarray(w), wb, cb)
    assert d.shape == (n_splits(wb, cb),) + w.shape
    r = recombine(d, wb, cb)
    assert np.array_equal(np.asarray(r), w)


@settings(max_examples=30, deadline=None)
@given(
    wb_cb=st.sampled_from([(3, 1), (4, 2), (8, 3)]),
    seed=st.integers(0, 2 ** 16),
)
def test_digit_ranges_fit_cells(wb_cb, seed):
    """Sign-magnitude differential encoding: each physical cell stores an
    unsigned digit < 2^c; the sign is the G+/G- pair assignment, so all of
    a weight's digits share its sign."""
    wb, cb = wb_cb
    rng = np.random.RandomState(seed)
    w = rng.randint(-(2 ** (wb - 1)), 2 ** (wb - 1), size=(64,)
                    ).astype(np.float32)
    d = np.asarray(split_digits(jnp.asarray(w), wb, cb))
    assert np.abs(d).max() < 2 ** cb
    # sign consistency per weight: no digit opposes its weight's sign
    signs = np.sign(w)[None, :]
    assert np.all(d * signs >= 0)


@settings(max_examples=40, deadline=None)
@given(
    wb_cb=st.sampled_from([(2, 1), (3, 1), (3, 2), (4, 2), (4, 3), (6, 2),
                           (6, 3), (8, 2), (8, 3)]),
    store=st.sampled_from(["int8", "int4"]),
    seed=st.integers(0, 2 ** 16),
)
def test_store_dtype_roundtrip(wb_cb, store, seed):
    """Digit planes survive the deploy storage cast losslessly: int8
    always; int4 whenever cells are <= 3 bits (|digit| <= 7) — the
    exact rule CIMConfig.store_dtype applies."""
    wb, cb = wb_cb
    rng = np.random.RandomState(seed)
    w = rng.randint(-(2 ** (wb - 1)), 2 ** (wb - 1), size=(17, 5)
                    ).astype(np.float32)
    d = split_digits(jnp.asarray(w), wb, cb)
    dt = jnp.int4 if (store == "int4" and cb <= 3) else jnp.int8
    stored = d.astype(dt)
    r = recombine(stored.astype(jnp.float32), wb, cb)
    assert np.array_equal(np.asarray(r), w)


@settings(max_examples=30, deadline=None)
@given(
    kn=st.sampled_from([(7, 3), (13, 31), (32, 33), (33, 32), (1, 1),
                        (50, 17)]),
    seed=st.integers(0, 2 ** 16),
)
def test_roundtrip_ragged_shapes(kn, seed):
    """Round trip is exact for ragged (K, N) that don't divide the CIM
    array dims — packing pads tiles, but the digits themselves are
    shape-agnostic."""
    k, n = kn
    rng = np.random.RandomState(seed)
    w = rng.randint(-8, 8, size=(k, n)).astype(np.float32)
    d = split_digits(jnp.asarray(w), 4, 2)
    assert d.shape == (2, k, n)
    assert np.array_equal(np.asarray(recombine(d, 4, 2)), w)


def test_place_values():
    assert np.allclose(np.asarray(place_values(4, 2)), [1.0, 4.0])
    assert np.allclose(np.asarray(place_values(3, 1)), [1.0, 2.0, 4.0])


def test_binary_weight_single_split():
    w = jnp.asarray([-1.0, 1.0, -1.0])
    d = split_digits(w, 1, 1)
    assert d.shape == (1, 3)
    assert np.array_equal(np.asarray(recombine(d, 1, 1)), np.asarray(w))
