"""Column-parallel sharded serving (DESIGN.md §10): bit-exactness of the
N-device deploy path against the single-device path.

These tests need a multi-device host; CI runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (they skip on a
plain single-device run, where tier-1 covers the unsharded paths).
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CIMConfig, DeployArtifact, QuantConv2d, QuantLinear,
                       Variation, model_artifact)
from repro.nn.module import set_activation_rules

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")


@pytest.fixture()
def mesh4():
    return jax.make_mesh((4,), ("model",))


@pytest.fixture()
def installed_mesh(mesh4):
    """Install mesh4 as the session mesh (what the serving engine does);
    always uninstall so later tests see the single-device world."""
    set_activation_rules({}, mesh4)
    yield mesh4
    set_activation_rules(None, None)


def _linear(n, pack_dtype="int8", use_kernel=True):
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    pack_dtype=pack_dtype, use_kernel=use_kernel)
    h = QuantLinear(40, n, cfg).init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 40))
    h.calibrate(x)
    return QuantLinear.from_artifact(h.pack()), x


# -- linear -----------------------------------------------------------------

@pytest.mark.parametrize("n", [24, 22])   # divisible and ragged over 4
@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_linear_sharded_bit_exact(mesh4, n, pack_dtype):
    served, x = _linear(n, pack_dtype)
    y1 = served(x)
    set_activation_rules({}, mesh4)
    try:
        y4 = served(x)
    finally:
        set_activation_rules(None, None)
    assert y4.shape == (6, n)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y4))


def test_linear_sharded_oracle_path(installed_mesh):
    """use_kernel=False (jnp oracle inside shard_map) is sharded too."""
    served, x = _linear(22, use_kernel=False)
    y4 = served(x)
    set_activation_rules(None, None)
    y1 = served(x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y4))


def test_linear_variation_threading_under_sharding(mesh4):
    """The same variation key draws the same device realization sharded
    and unsharded — noise is drawn on the full packed planes pre-shard."""
    served, x = _linear(22)   # ragged: noise indices must survive padding
    var = Variation(jax.random.PRNGKey(7), 0.2)
    clean1, noisy1 = served(x), served(x, variation=var)
    set_activation_rules({}, mesh4)
    try:
        noisy4 = served(x, variation=var)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(noisy1), np.asarray(noisy4))
    assert not np.array_equal(np.asarray(clean1), np.asarray(noisy1))


# -- nibble planes + occupancy maps (layout v4, DESIGN.md §14) --------------

def test_linear_nibble_occ_sharded_bit_exact_under_variation(mesh4):
    """int4 planes stream as packed uint8 bytes with their occupancy maps
    through shard_map: 4-device output == 1-device output bit-exactly,
    clean AND under a shared variation key, on a ragged column count
    (byte-aligned shard boundaries + occ padded with dead columns)."""
    from repro.core.nibble import is_nibble_packed
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    pack_dtype="int4")
    h = QuantLinear(64, 22, cfg).init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 64))
    h.calibrate(x)
    # dead planes: tile 0 zeroed for a column band -> occ has real zeros
    h.params = dict(h.params, w=h.params["w"].at[:32, 4:12].set(0.0))
    art = h.pack()
    assert is_nibble_packed(art.params["w_digits"])       # uint8 storage
    occ = np.asarray(art.params["w_occ"])
    assert occ.min() == 0 and occ.max() == 1              # skip path live
    served = QuantLinear.from_artifact(art)

    var = Variation(jax.random.PRNGKey(7), 0.2)
    clean1, noisy1 = served(x), served(x, variation=var)
    set_activation_rules({}, mesh4)
    try:
        clean4, noisy4 = served(x), served(x, variation=var)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(clean1), np.asarray(clean4))
    np.testing.assert_array_equal(np.asarray(noisy1), np.asarray(noisy4))
    assert not np.array_equal(np.asarray(clean1), np.asarray(noisy1))


def test_conv_nibble_occ_sharded_bit_exact(mesh4):
    """Conv analog: array_rows=36 with 3x3 taps gives an even
    c_per_array=4, so int4 conv planes nibble-pack along the channel
    axis; ragged c_out=10 over 4 devices."""
    from repro.core.nibble import is_nibble_packed
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=36, array_cols=32,
                    act_signed=False, pack_dtype="int4")
    h = QuantConv2d(3, 3, 8, 10, cfg, stride=2).init(jax.random.PRNGKey(2))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(3), (2, 9, 9, 8)))
    h.calibrate(x)
    h.params = dict(h.params, w=h.params["w"].at[:, :, :4, 2:6].set(0.0))
    art = h.pack()
    assert is_nibble_packed(art.params["w_digits"])
    assert np.asarray(art.params["w_occ"]).min() == 0
    served = QuantConv2d.from_artifact(art)

    y1 = served(x)
    set_activation_rules({}, mesh4)
    try:
        y4 = served(x)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y4))


# -- conv -------------------------------------------------------------------

def _conv(c_out, pack_dtype="int8", stride=2, padding="SAME"):
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    act_signed=False, pack_dtype=pack_dtype)
    h = QuantConv2d(3, 3, 8, c_out, cfg, stride=stride,
                    padding=padding).init(jax.random.PRNGKey(2))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(3), (2, 9, 9, 8)))
    h.calibrate(x)
    return QuantConv2d.from_artifact(h.pack()), x


@pytest.mark.parametrize("c_out", [16, 10])   # divisible and ragged over 4
@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_conv_sharded_bit_exact(mesh4, c_out, pack_dtype):
    served, x = _conv(c_out, pack_dtype)
    y1 = served(x)
    set_activation_rules({}, mesh4)
    try:
        y4 = served(x)
    finally:
        set_activation_rules(None, None)
    assert y4.shape[-1] == c_out
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y4))


def test_conv_sharded_valid_padding_stride1(mesh4):
    served, x = _conv(10, stride=1, padding="VALID")
    y1 = served(x)
    set_activation_rules({}, mesh4)
    try:
        y4 = served(x)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y4))


def test_conv_variation_threading_under_sharding(mesh4):
    served, x = _conv(10)
    var = Variation(jax.random.PRNGKey(9), 0.15)
    noisy1 = served(x, variation=var)
    set_activation_rules({}, mesh4)
    try:
        noisy4 = served(x, variation=var)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(noisy1), np.asarray(noisy4))


# -- artifacts + engine -----------------------------------------------------

def _lm_artifact():
    from repro.configs.registry import get_config
    from repro.models.registry import get_model
    from repro.nn import init_params
    cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                    use_kernel=False)
    cfg = get_config("qwen3-0.6b", reduced=True, cim=cim).replace(
        compute_dtype="float32")
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    return model_artifact(params, cim), cfg, model


def test_artifact_load_places_planes_sharded(mesh4):
    art, cfg, model = _lm_artifact()
    assert art.meta["col_shard"]            # pack_model recorded the axes
    assert all(ax == -1 for ax in art.meta["col_shard"].values())
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        sharded = DeployArtifact.load(d, mesh=mesh4)
    found_sharded = 0
    for path in art.meta["col_shard"]:
        node = sharded.params
        for part in path.split("/"):
            node = node[int(part)] if isinstance(node, list) else node[part]
        planes = node["w_digits"]
        n = planes.shape[-1]
        spec = planes.sharding.spec
        if n % 4 == 0:
            assert spec[-1] == "model", (path, spec)
            found_sharded += 1
        # ragged columns stay replicated; the kernel wrapper pads per call
    assert found_sharded > 0
    # placement must not change values
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(art.params)[0]),
        np.asarray(jax.tree.leaves(sharded.params)[0]))


def test_model_logits_bit_exact_sharded(mesh4):
    art, cfg, model = _lm_artifact()
    serve_cfg = dataclasses.replace(cfg, cim=art.config)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (2, 8)), jnp.int32)
    logits1 = model.forward(art.params, toks, serve_cfg)
    sharded = art.shard(mesh4)
    set_activation_rules({}, mesh4)
    try:
        logits4 = model.forward(sharded.params, toks, serve_cfg)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits4))


def test_engine_sharded_generation_matches(mesh4):
    from repro.serve.engine import engine_from_artifact
    art, cfg, _ = _lm_artifact()
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 8)
                                               ).astype(np.int32)
    eng1 = engine_from_artifact(art, cfg, batch_size=2, max_len=64)
    out1 = eng1.generate_batch(prompts, 6)
    try:
        eng4 = engine_from_artifact(art, cfg, mesh=mesh4, batch_size=2,
                                    max_len=64)
        out4 = eng4.generate_batch(prompts, 6)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(out1, out4)


# -- self-healing serving (DESIGN.md §11) -----------------------------------

def test_drift_logits_bit_exact_sharded(mesh4):
    """Same drift key + same request clock => the 4-device deploy path
    sees the SAME chip realization as the 1-device path, bit-exactly —
    the drift field is drawn on the full packed planes pre-shard, like
    static variation."""
    from repro.core.variation import DriftSchedule, DriftState
    art, cfg, model = _lm_artifact()
    serve_cfg = dataclasses.replace(cfg, cim=art.config)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (2, 8)), jnp.int32)
    from repro.core.variation import drift_tree
    sched = DriftSchedule(read_sigma=0.02, cell_rate=2e-4, col_rate=1e-3)
    state = DriftState(sched, jnp.int32(200))
    key = jax.random.PRNGKey(7)
    p1 = drift_tree(art.params, key, state)
    logits1 = model.forward(p1, toks, serve_cfg)
    sharded = art.shard(mesh4)
    set_activation_rules({}, mesh4)
    try:
        p4 = drift_tree(sharded.params, key, state)
        logits4 = model.forward(p4, toks, serve_cfg)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits4))


def test_engine_drift_generation_bit_exact_sharded(mesh4):
    from repro.core.variation import DriftSchedule
    from repro.serve.engine import engine_from_artifact
    art, cfg, _ = _lm_artifact()
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 8)
                                               ).astype(np.int32)
    sched = DriftSchedule(read_sigma=0.02, cell_rate=2e-4, col_rate=1e-3)
    kw = dict(batch_size=2, max_len=64, drift_key=jax.random.PRNGKey(7),
              drift_schedule=sched)
    eng1 = engine_from_artifact(art, cfg, **kw)
    eng1.t = 150
    out1 = eng1.generate_batch(prompts, 6)
    try:
        eng4 = engine_from_artifact(art, cfg, mesh=mesh4, **kw)
        eng4.t = 150
        out4 = eng4.generate_batch(prompts, 6)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(out1, out4)


def test_scale_delta_apply_sharded_bit_exact(mesh4):
    """Applying a ScaleDelta to a column-sharded artifact is bit-exact
    with applying it to the unsharded one — each device updates only its
    own column slice (acceptance criterion)."""
    from repro.core.variation import DriftSchedule, drift_tree
    from repro.eval.recalibrate import apply_scale_delta, fit_scale_delta
    art, cfg, model = _lm_artifact()
    sched = DriftSchedule(cell_rate=2e-4, col_rate=1e-3)
    drifted = drift_tree(art.params, jax.random.PRNGKey(7), sched.at(300))
    delta = fit_scale_delta(art, drifted, key=jax.random.PRNGKey(3),
                            probes=16)
    recal1 = apply_scale_delta(art, delta)
    sharded = art.shard(mesh4)
    recal4 = apply_scale_delta(sharded, delta)
    assert recal4.meta["delta_version"] == delta.delta_version

    def leaves_by_path(tree):
        out = {}

        def walk(node, path):
            if isinstance(node, dict):
                if "w_digits" in node:
                    out["/".join(path)] = node
                    return
                for k, v in node.items():
                    walk(v, path + (k,))
            elif isinstance(node, (list, tuple)):
                for i, v in enumerate(node):
                    walk(v, path + (str(i),))
        walk(tree, ())
        return out

    n1, n4 = leaves_by_path(recal1.params), leaves_by_path(recal4.params)
    assert set(n1) == set(n4) and n1
    for name in n1:
        for leaf in ("s_p", "deq_scale"):
            a = np.asarray(n1[name][leaf])
            b = np.asarray(n4[name][leaf])
            np.testing.assert_array_equal(a, b, err_msg=f"{name}/{leaf}")
        # sharded apply keeps the gain column-sharded on divisible nodes
        if n4[name]["w_digits"].shape[-1] % 4 == 0:
            spec = n4[name]["deq_scale"].sharding.spec
            assert len(spec) == 0 or spec[-1] in ("model", None)

    # end-to-end: recalibrated logits agree bit-exactly too
    toks = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab, (2, 6)), jnp.int32)
    serve_cfg = dataclasses.replace(cfg, cim=art.config)
    logits1 = model.forward(recal1.params, toks, serve_cfg)
    set_activation_rules({}, mesh4)
    try:
        logits4 = model.forward(recal4.params, toks, serve_cfg)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits4))
