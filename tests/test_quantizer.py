"""Property tests for the LSQ quantizer (paper §III-A / ref [10])."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.granularity import ArrayTiling, Granularity
from repro.core.quantizer import (init_scale_from, lsq_fake_quant, qrange,
                                  round_ste)


@given(bits=st.integers(2, 8), signed=st.booleans())
def test_qrange_levels(bits, signed):
    qn, qp = qrange(bits, signed)
    assert qp - qn + 1 == 2 ** bits
    if signed:
        assert qn < 0 < qp + 1


@settings(max_examples=50, deadline=None)
@given(
    bits=st.integers(2, 8),
    scale=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2 ** 16),
)
def test_lsq_on_grid_and_bounded_error(bits, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    s = jnp.asarray(scale, jnp.float32)
    y = lsq_fake_quant(x, s, bits)
    qn, qp = qrange(bits, True)
    codes = np.asarray(y) / scale
    assert np.all(codes >= qn - 1e-4) and np.all(codes <= qp + 1e-4)
    # quantized values sit on the integer grid
    assert np.allclose(codes, np.round(codes), atol=1e-4)
    # in-range inputs are within half a step
    inside = (np.asarray(x) / scale >= qn) & (np.asarray(x) / scale <= qp)
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert np.all(err[inside] <= 0.5 * scale + 1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bits=st.integers(2, 6))
def test_lsq_idempotent(seed, bits):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32,))
    s = jnp.asarray(0.1, jnp.float32)
    y1 = lsq_fake_quant(x, s, bits)
    y2 = lsq_fake_quant(y1, s, bits)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_lsq_gradients_ste():
    x = jnp.asarray([0.04, -0.26, 5.0, -5.0])  # in, in, clipped hi, lo
    s = jnp.asarray(0.1, jnp.float32)
    bits = 3                                    # range [-4, 3]

    gx = jax.grad(lambda x_: lsq_fake_quant(x_, s, bits).sum())(x)
    # STE: unit gradient inside the clip range, zero outside
    assert np.allclose(np.asarray(gx), [1.0, 1.0, 0.0, 0.0])

    gs = jax.grad(lambda s_: lsq_fake_quant(x, s_, bits).sum())(s)
    assert np.isfinite(float(gs))
    # clipped values pull the scale up (positive qp/qn contributions dominate)
    g_hi = jax.grad(lambda s_: lsq_fake_quant(jnp.asarray([5.0]), s_, bits
                                              ).sum())(s)
    assert float(g_hi) > 0


def test_binary_sign_quantization():
    x = jnp.asarray([-0.4, -0.01, 0.02, 3.0])
    s = jnp.asarray(0.5, jnp.float32)
    y = lsq_fake_quant(x, s, bits=1)
    assert np.allclose(np.asarray(y), [-0.5, -0.5, 0.5, 0.5])


def test_round_ste_grad_is_identity():
    g = jax.grad(lambda x: round_ste(x).sum())(jnp.asarray([0.3, 1.7]))
    assert np.allclose(np.asarray(g), 1.0)


@settings(max_examples=40, deadline=None)
@given(
    kn=st.sampled_from([(48, 40), (33, 17), (100, 7), (31, 65), (5, 3)]),
    g=st.sampled_from([Granularity.LAYER, Granularity.ARRAY,
                       Granularity.COLUMN]),
    seed=st.integers(0, 2 ** 16),
)
def test_granularity_modes_quantize_on_group_grid(kn, g, seed):
    """All three granularity modes (paper Fig. 1), including ragged (K, N)
    that don't divide the array dims: a scale parameter of the mode's
    shape broadcasts to (k_tiles, N), and fake-quant with the broadcast
    scale puts every element on its own group's integer grid."""
    k, n = kn
    t = ArrayTiling(k=k, n=n, array_rows=32, array_cols=32,
                    weight_bits=4, cell_bits=2)
    rng = np.random.RandomState(seed)
    s = jnp.asarray(rng.uniform(0.05, 2.0, t.weight_scale_shape(g)),
                    jnp.float32)
    full = t.broadcast_weight_scale(s)
    assert full.shape == (t.k_tiles, t.n)
    # quantize a (k_tiles, N) tensor with per-group scales
    x = jnp.asarray(rng.randn(t.k_tiles, n), jnp.float32)
    y = lsq_fake_quant(x, full, bits=4,
                       group_size=t.weight_group_size(g))
    codes = np.asarray(y) / np.asarray(full)
    assert np.allclose(codes, np.round(codes), atol=1e-4)
    qn, qp = qrange(4, True)
    assert codes.min() >= qn - 1e-4 and codes.max() <= qp + 1e-4
    # the psum side indexes (split, k_tile, col); same broadcast contract
    sp = jnp.asarray(rng.uniform(0.05, 2.0, t.psum_scale_shape(g)),
                     jnp.float32)
    assert t.broadcast_psum_scale(sp).shape == (t.n_split, t.k_tiles, t.n)


@settings(max_examples=20, deadline=None)
@given(
    kn=st.sampled_from([(48, 40), (33, 17), (100, 7)]),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2 ** 16),
)
def test_init_scale_shapes_and_positivity(kn, bits, seed):
    """LSQ scale init produces strictly positive scales at the parameter
    shape of every granularity mode."""
    k, n = kn
    t = ArrayTiling(k=k, n=n, array_rows=32, array_cols=32,
                    weight_bits=4, cell_bits=2)
    x = jax.random.normal(jax.random.PRNGKey(seed), (t.k_tiles, t.n))
    for g, axes in ((Granularity.LAYER, (0, 1)), (Granularity.COLUMN, ())):
        shape = t.weight_scale_shape(g)
        s = init_scale_from(x, bits, axes, shape)
        assert s.shape == shape
        assert bool(jnp.all(s > 0))


def test_dequant_muls_column_alignment_is_free():
    """Paper Fig. 4: aligning weights AND psums at COLUMN costs exactly
    as many dequant muls as LAYER-weight + COLUMN-psum — the zero-overhead
    observation that motivates column-wise weight scales."""
    t = ArrayTiling(k=96, n=64, array_rows=32, array_cols=32,
                    weight_bits=4, cell_bits=2)
    both_col = t.dequant_muls(Granularity.COLUMN, Granularity.COLUMN)
    layer_w = t.dequant_muls(Granularity.LAYER, Granularity.COLUMN)
    assert both_col == layer_w
    assert t.dequant_muls(Granularity.LAYER, Granularity.LAYER) == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_per_column_scales_broadcast(seed):
    """Column-wise scales quantize each column at its own step size."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 4))
    s = jnp.asarray([[0.01, 0.1, 1.0, 10.0]], jnp.float32)
    y = lsq_fake_quant(x, s, bits=4)
    for c, sc in enumerate([0.01, 0.1, 1.0, 10.0]):
        codes = np.asarray(y)[:, c] / sc
        assert np.allclose(codes, np.round(codes), atol=1e-3)
