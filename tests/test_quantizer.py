"""Property tests for the LSQ quantizer (paper §III-A / ref [10])."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.quantizer import lsq_fake_quant, qrange, round_ste


@given(bits=st.integers(2, 8), signed=st.booleans())
def test_qrange_levels(bits, signed):
    qn, qp = qrange(bits, signed)
    assert qp - qn + 1 == 2 ** bits
    if signed:
        assert qn < 0 < qp + 1


@settings(max_examples=50, deadline=None)
@given(
    bits=st.integers(2, 8),
    scale=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2 ** 16),
)
def test_lsq_on_grid_and_bounded_error(bits, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    s = jnp.asarray(scale, jnp.float32)
    y = lsq_fake_quant(x, s, bits)
    qn, qp = qrange(bits, True)
    codes = np.asarray(y) / scale
    assert np.all(codes >= qn - 1e-4) and np.all(codes <= qp + 1e-4)
    # quantized values sit on the integer grid
    assert np.allclose(codes, np.round(codes), atol=1e-4)
    # in-range inputs are within half a step
    inside = (np.asarray(x) / scale >= qn) & (np.asarray(x) / scale <= qp)
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert np.all(err[inside] <= 0.5 * scale + 1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bits=st.integers(2, 6))
def test_lsq_idempotent(seed, bits):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32,))
    s = jnp.asarray(0.1, jnp.float32)
    y1 = lsq_fake_quant(x, s, bits)
    y2 = lsq_fake_quant(y1, s, bits)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_lsq_gradients_ste():
    x = jnp.asarray([0.04, -0.26, 5.0, -5.0])  # in, in, clipped hi, lo
    s = jnp.asarray(0.1, jnp.float32)
    bits = 3                                    # range [-4, 3]

    gx = jax.grad(lambda x_: lsq_fake_quant(x_, s, bits).sum())(x)
    # STE: unit gradient inside the clip range, zero outside
    assert np.allclose(np.asarray(gx), [1.0, 1.0, 0.0, 0.0])

    gs = jax.grad(lambda s_: lsq_fake_quant(x, s_, bits).sum())(s)
    assert np.isfinite(float(gs))
    # clipped values pull the scale up (positive qp/qn contributions dominate)
    g_hi = jax.grad(lambda s_: lsq_fake_quant(jnp.asarray([5.0]), s_, bits
                                              ).sum())(s)
    assert float(g_hi) > 0


def test_binary_sign_quantization():
    x = jnp.asarray([-0.4, -0.01, 0.02, 3.0])
    s = jnp.asarray(0.5, jnp.float32)
    y = lsq_fake_quant(x, s, bits=1)
    assert np.allclose(np.asarray(y), [-0.5, -0.5, 0.5, 0.5])


def test_round_ste_grad_is_identity():
    g = jax.grad(lambda x: round_ste(x).sum())(jnp.asarray([0.3, 1.7]))
    assert np.allclose(np.asarray(g), 1.0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_per_column_scales_broadcast(seed):
    """Column-wise scales quantize each column at its own step size."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 4))
    s = jnp.asarray([[0.01, 0.1, 1.0, 10.0]], jnp.float32)
    y = lsq_fake_quant(x, s, bits=4)
    for c, sc in enumerate([0.01, 0.1, 1.0, 10.0]):
        codes = np.asarray(y)[:, c] / sc
        assert np.allclose(codes, np.round(codes), atol=1e-3)
