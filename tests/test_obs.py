"""Telemetry plane (DESIGN.md §12): metrics registry semantics, exact
percentiles, span nesting, engine lifecycle metrics, and the sampled
per-column ADC saturation counters — including the zero-overhead
contract: the deploy output with instrumentation armed is bit-exact with
the un-instrumented output, and counters match a numpy oracle."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CIMConfig, calibrate_conv, calibrate_linear, conv2d,
                       init_conv, init_linear, linear, pack_conv,
                       pack_linear)
from repro.obs import MetricsRegistry, Tracer, adc
from repro.obs import names as M


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_snapshot_and_reset(tmp_path):
    log = tmp_path / "events.jsonl"
    reg = MetricsRegistry(event_log_path=str(log))
    reg.counter("a.count").inc()
    reg.counter("a.count").inc(4)
    reg.gauge("a.depth").set(7)
    reg.histogram("a.lat").observe(1.0)
    reg.histogram("a.lat").observe(3.0)
    reg.log_event("thing", rid=1)

    snap = reg.snapshot()
    assert snap["counters"]["a.count"] == 5
    assert snap["gauges"]["a.depth"] == 7.0
    h = snap["histograms"]["a.lat"]
    assert h["count"] == 2 and h["sum"] == 4.0 and h["p50"] == 2.0
    assert json.dumps(snap)                      # JSON-safe by contract
    assert len(reg.events("thing")) == 1

    # counter/gauge/histogram objects handed out before reset keep
    # working; everything restarts from zero
    c = reg.counter("a.count")
    reg.reset()
    snap = reg.snapshot()
    assert snap["counters"]["a.count"] == 0
    assert snap["gauges"]["a.depth"] == 0.0
    assert snap["histograms"]["a.lat"] == {"count": 0, "sum": 0.0}
    assert reg.events() == []
    c.inc()
    assert reg.snapshot()["counters"]["a.count"] == 1

    # the JSONL file is append-only and survives the reset
    lines = [json.loads(s) for s in log.read_text().splitlines()]
    assert [e["kind"] for e in lines] == ["thing"]
    assert lines[0]["rid"] == 1 and "ts" in lines[0]


def test_histogram_percentiles_match_numpy():
    rng = np.random.RandomState(0)
    vals = rng.lognormal(size=500)
    reg = MetricsRegistry()
    h = reg.histogram("x")
    for v in vals:
        h.observe(v)
    for q in (0, 25, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(
            np.percentile(vals, q), rel=1e-12)
    s = h.summary()
    assert s["count"] == 500
    assert s["mean"] == pytest.approx(vals.mean())
    assert s["min"] == vals.min() and s["max"] == vals.max()


def test_histogram_cap_decimates_but_keeps_exact_count_sum():
    reg = MetricsRegistry()
    h = reg.histogram("x", max_samples=64)
    n = 1000
    for v in range(n):
        h.observe(float(v))
    assert h.count == n
    assert h.sum == float(n * (n - 1) // 2)
    assert h.min == 0.0 and h.max == float(n - 1)
    # decimated percentiles stay in range and ordered
    p50, p99 = h.percentile(50), h.percentile(99)
    assert 0.0 <= p50 <= p99 <= float(n - 1)
    assert len(h._values) < 2 * 64


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("serve.tokens.generated").inc(3)
    reg.gauge("serve.queue.depth").set(2)
    for v in (1.0, 2.0, 3.0):
        reg.histogram("serve.request.latency.seconds").observe(v)
    text = reg.to_prometheus()
    assert "# TYPE serve_tokens_generated counter" in text
    assert "serve_tokens_generated 3" in text
    assert "serve_queue_depth 2.0" in text
    assert 'serve_request_latency_seconds{quantile="0.5"} 2.0' in text
    assert "serve_request_latency_seconds_count 3" in text
    assert "." not in text.split("serve_tokens_generated")[1].split()[0]


def test_span_nesting_and_histogram():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    with tr.span("outer", rid=1):
        with tr.span("inner"):
            pass
    assert [s.name for s in tr.spans] == ["inner", "outer"]
    inner, outer = tr.spans
    assert inner.parent == "outer" and outer.parent is None
    assert outer.duration >= inner.duration >= 0.0
    assert reg.histogram("outer.seconds").count == 1
    assert reg.histogram("inner.seconds").count == 1
    evs = reg.events("span")
    assert {e["name"] for e in evs} == {"outer", "inner"}
    assert next(e for e in evs if e["name"] == "inner")["parent"] == "outer"


# ---------------------------------------------------------------------------
# ADC saturation collector
# ---------------------------------------------------------------------------

def _lin_setup(psum_bits=4, seed=0, k=70, n=24, b=8):
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=6, psum_bits=psum_bits, array_rows=32,
                    array_cols=32)
    p = init_linear(jax.random.PRNGKey(seed), k, n, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, k)) * 0.5
    return calibrate_linear(x, p, cfg), x, cfg


def test_saturation_stats_match_numpy_oracle():
    rng = np.random.RandomState(1)
    psum = rng.randint(-40, 40, size=(6, 2, 3, 10)).astype(np.float32)
    s_p = rng.uniform(0.5, 2.0, size=(2, 3, 10)).astype(np.float32)
    bits = 4
    sat, occ = adc.saturation_stats(jnp.asarray(psum), jnp.asarray(s_p), bits)
    q = np.round(np.round(psum) / s_p)
    qn, qp = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    exp_sat = ((q < qn) | (q > qp)).sum(axis=(0, 1, 2))
    assert np.array_equal(np.asarray(sat), exp_sat)
    exp_occ = (np.abs(np.clip(q, qn, qp)) / qp).mean(axis=(0, 1, 2))
    np.testing.assert_allclose(np.asarray(occ), exp_occ, rtol=1e-6)
    # sign ADC never clips
    sat1, occ1 = adc.saturation_stats(jnp.asarray(psum), jnp.asarray(s_p), 1)
    assert int(np.asarray(sat1).sum()) == 0
    assert np.all(np.asarray(occ1) == 1.0)


def test_emulate_counters_exact():
    """emulate materializes every psum, so armed counters are exact:
    conversions == B * n_split * k_tiles * N."""
    p, x, cfg = _lin_setup(psum_bits=3)   # narrow ADC: some clipping
    with adc.sampled() as reg:
        linear(x, p, cfg)
        adc.sync()
        s = adc.summary()
    assert s["conversions"] == 8 * 2 * 3 * 24   # b, S, k_tiles(70/32), n
    assert 0 <= s["saturated"] <= s["conversions"]
    assert reg.counter(M.ADC_CONVERSIONS).value == s["conversions"]
    assert reg.histogram(M.ADC_COL_SATURATION_RATE).count == 24


@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_deploy_bit_exact_with_instrumentation(pack_dtype):
    """The zero-overhead contract (ISSUE acceptance): deploy output with
    the collector armed is bit-exact with instrumentation absent, and
    disarming restores the un-instrumented trace."""
    p, x, cfg = _lin_setup()
    dcfg = cfg.replace(mode="deploy", pack_dtype=pack_dtype)
    packed = pack_linear(p, dcfg)

    y_off = np.asarray(linear(x, packed, dcfg))
    with adc.sampled() as reg:
        y_on = np.asarray(linear(x, packed, dcfg))
        adc.sync()
        s = adc.summary()
    y_after = np.asarray(linear(x, packed, dcfg))

    assert np.array_equal(y_off, y_on)
    assert np.array_equal(y_off, y_after)
    assert s["conversions"] == 8 * 2 * 3 * 24
    # deploy counters agree with the emulate (materialized-psum) oracle
    with adc.sampled():
        linear(x, p, cfg)
        adc.sync()
        assert adc.summary()["saturated"] == s["saturated"]


def test_conv_deploy_bit_exact_with_instrumentation():
    cfg = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=6, psum_bits=4, array_rows=32, array_cols=32)
    p = init_conv(jax.random.PRNGKey(2), 3, 3, 8, 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 8, 8)) * 0.5
    p = calibrate_conv(x, p, cfg)
    dcfg = cfg.replace(mode="deploy")
    packed = pack_conv(p, dcfg)

    y_off = np.asarray(conv2d(x, packed, dcfg))
    with adc.sampled():
        y_on = np.asarray(conv2d(x, packed, dcfg))
        adc.sync()
        s = adc.summary()
    assert np.array_equal(y_off, y_on)
    assert s["conversions"] == 2 * 8 * 8 * 2 * 3 * 16  # b,ho,wo,S,kt,co
    # emulate agrees
    with adc.sampled():
        conv2d(x, p, cfg)
        adc.sync()
        assert adc.summary()["saturated"] == s["saturated"]


def test_every_n_decimates_folding():
    p, x, cfg = _lin_setup()
    with adc.sampled(every_n=3):
        for _ in range(7):
            linear(x, p, cfg)
        adc.sync()
        s = adc.summary()
    assert s["kernel_invocations"] == 7
    assert s["samples_folded"] == 3                    # calls 1, 4, 7
    assert s["conversions"] == 3 * 8 * 2 * 3 * 24


def test_disable_stops_stale_armed_trace():
    """A function traced while armed stops folding the moment the
    collector disarms (host-side check in the callback)."""
    p, x, cfg = _lin_setup()
    fwd = jax.jit(lambda xx: linear(xx, p, cfg))
    adc.enable()
    try:
        fwd(x)
        adc.sync()
        before = adc.totals()
        assert before[1] > 0
    finally:
        adc.disable()
    fwd(x)                                   # stale armed trace
    adc.sync()
    assert adc.totals() == before
    adc.reset()


@pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")
def test_sharded_deploy_counters_and_bit_exactness():
    """Armed counters on the column-sharded dispatch match the
    single-device counts (the side-output einsums the full pre-shard
    planes), and the sharded output stays bit-exact."""
    from repro.nn.module import session_mesh
    p, x, cfg = _lin_setup()
    dcfg = cfg.replace(mode="deploy", use_kernel=False)
    packed = pack_linear(p, dcfg)
    y1 = np.asarray(linear(x, packed, dcfg))
    with adc.sampled():
        linear(x, packed, dcfg)
        adc.sync()
        single = adc.summary()
    mesh = jax.make_mesh((4,), ("model",))
    with session_mesh(mesh):
        with adc.sampled():
            y4 = np.asarray(linear(x, packed, dcfg))
            adc.sync()
            sharded = adc.summary()
    assert np.array_equal(y1, y4)
    assert sharded["conversions"] == single["conversions"]
    assert sharded["saturated"] == single["saturated"]


# ---------------------------------------------------------------------------
# engine lifecycle metrics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs.registry import get_config
    from repro.models.registry import get_model
    from repro.nn import init_params
    cfg = get_config("qwen3-0.6b", reduced=True).replace(
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_metrics_scripted_requests(lm_setup):
    from repro.serve.engine import ServingEngine
    cfg, model, params = lm_setup
    eng = ServingEngine(model, cfg, params, batch_size=2, max_len=64)
    eng.submit([3, 5, 7], max_new_tokens=4)
    eng.submit([11, 13], max_new_tokens=2)
    eng.submit([2], max_new_tokens=3)
    done = 0
    for _ in range(30):
        done += len(eng.step())
        if done == 3:
            break
    assert done == 3

    m = eng.metrics()
    h = m["health"]
    assert h["submitted"] == 3 and h["retired"] == 3
    assert h["queue_depth"] == 0 and h["active_slots"] == 0
    assert h["slots"] == 2

    snap = m["metrics"]
    assert snap["counters"][M.REQUESTS_SUBMITTED] == 3
    assert snap["counters"][M.REQUESTS_COMPLETED] == 3
    assert snap["counters"][M.TOKENS_GENERATED] >= 4 + 2 + 3
    assert snap["histograms"][M.REQUEST_LATENCY_SECONDS]["count"] == 3
    assert snap["histograms"][M.QUEUE_WAIT_SECONDS]["count"] == 3
    assert snap["histograms"][M.PREFILL_SECONDS]["count"] == 3
    assert snap["histograms"][M.DECODE_STEP_SECONDS]["count"] >= 4
    assert m["throughput"]["tokens_per_sec"] > 0
    assert m["saturation"] is None               # collector not armed

    evs = eng.registry.events("request_completed")
    assert sorted(e["rid"] for e in evs) == [0, 1, 2]
    assert {e["tokens"] for e in evs} == {4, 2, 3}
    assert json.dumps(m["metrics"])              # JSON-safe end to end
