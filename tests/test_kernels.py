"""Pallas cim_matmul kernel vs the pure-jnp oracle: shape/dtype/bit sweeps
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(m, k_tiles, rows, n, n_split, seed=0, digit_max=3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    a = jnp.round(jax.random.normal(ks[0], (m, k_tiles, rows)) * 4)
    digits = jax.random.randint(ks[1], (n_split, k_tiles, rows, n),
                                -digit_max, digit_max + 1).astype(jnp.int8)
    s_p = jax.random.uniform(ks[2], (n_split, k_tiles, n), minval=0.5,
                             maxval=20.0)
    deq = jax.random.uniform(ks[3], (n_split, k_tiles, n), minval=0.01,
                             maxval=0.1)
    return a, digits, s_p, deq


SHAPES = [
    (8, 1, 32, 16, 1),
    (16, 2, 64, 24, 2),
    (64, 3, 128, 40, 2),
    (128, 2, 128, 128, 3),
    (5, 2, 33, 7, 2),        # awkward/non-aligned
    (130, 1, 256, 129, 1),   # > one block in both dims
]


@pytest.mark.parametrize("m,k_tiles,rows,n,n_split", SHAPES)
@pytest.mark.parametrize("psum_bits", [1, 4, 8])
def test_kernel_matches_ref(m, k_tiles, rows, n, n_split, psum_bits):
    a, digits, s_p, deq = _mk(m, k_tiles, rows, n, n_split)
    out_k = ops.cim_matmul(a, digits, s_p, deq, psum_bits=psum_bits,
                           use_kernel=True)
    out_r = ops.cim_matmul(a, digits, s_p, deq, psum_bits=psum_bits,
                           use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("psum_quant", [True, False])
def test_kernel_psum_quant_toggle(psum_quant):
    a, digits, s_p, deq = _mk(32, 2, 64, 32, 2)
    out_k = ops.cim_matmul(a, digits, s_p, deq, psum_bits=4,
                           psum_quant=psum_quant, use_kernel=True)
    out_r = ops.cim_matmul(a, digits, s_p, deq, psum_bits=4,
                           psum_quant=psum_quant, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


def test_kernel_no_quant_equals_plain_matmul():
    """With psum quantization off and unit scales, the kernel is exactly a
    (bit-recombined) matmul."""
    from repro.core.bitsplit import place_values
    m, k_tiles, rows, n = 16, 2, 32, 8
    a, digits, _, _ = _mk(m, k_tiles, rows, n, 2)
    places = place_values(4, 2)
    deq = jnp.broadcast_to(places[:, None, None], (2, k_tiles, n))
    s_p = jnp.ones((2, k_tiles, n))
    out = ops.cim_matmul(a, digits, s_p, deq, psum_bits=8, psum_quant=False,
                         use_kernel=True)
    w = jnp.tensordot(places, digits.astype(jnp.float32), axes=(0, 0))
    expect = jnp.einsum("mtr,trn->mn", a, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


def test_kernel_batch_dims():
    a, digits, s_p, deq = _mk(24, 2, 64, 16, 2)
    a3 = a.reshape(2, 3, 4, 2, 64)
    out = ops.cim_matmul(a3, digits, s_p, deq, psum_bits=4, use_kernel=True)
    assert out.shape == (2, 3, 4, 16)
    flat = ops.cim_matmul(a, digits, s_p, deq, psum_bits=4, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out).reshape(24, 16),
                               np.asarray(flat), rtol=1e-6)


def test_adc_ref_binary():
    p = jnp.asarray([[-3.0, 0.5]])
    s = jnp.asarray([[2.0, 2.0]])
    out = ref.adc_quantize_ref(p, s, 1)
    np.testing.assert_allclose(np.asarray(out), [[-2.0, 2.0]])
