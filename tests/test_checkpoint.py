"""Checkpointing: roundtrip, atomicity, retention, async, elastic restore."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step, restore,
                                   save)


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": (jnp.zeros((2,)), jnp.asarray(3, jnp.int32))},
            "step": np.asarray(7, np.int64)}


def test_roundtrip(tmp_path):
    tree = _tree()
    save(str(tmp_path), 7, tree)
    out = restore(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_tree_preserves_empty_containers(tmp_path):
    """Leafless nodes (e.g. parameter-free norm dicts) are part of the
    tree structure: template-free restore must reinstate them, not drop
    them — a forward over the restored tree would KeyError otherwise."""
    from repro.checkpoint.ckpt import restore_tree
    tree = {"layers": {"ln1": {}, "attn": {"w": jnp.ones((2, 2))},
                       "taps": []},
            "x": jnp.zeros((3,))}
    save(str(tmp_path), 0, tree)
    out = restore_tree(str(tmp_path))
    assert out["layers"]["ln1"] == {}
    assert out["layers"]["taps"] == []
    assert jax.tree.structure(tree) == jax.tree.structure(out)
    # templated restore is unaffected
    out2 = restore(str(tmp_path), tree)
    assert jax.tree.structure(tree) == jax.tree.structure(out2)


def test_atomicity_no_partial_checkpoints(tmp_path):
    """A .tmp directory must never be picked up as a valid checkpoint."""
    tree = _tree()
    save(str(tmp_path), 1, tree)
    # simulate a crash mid-save: leave a stale tmp dir without manifest
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "leaf_00000.npy").write_bytes(b"junk")
    assert latest_step(str(tmp_path)) == 1
    out = restore(str(tmp_path), tree)
    assert int(np.asarray(out["step"])) == 7


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray([s])})
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004"]


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3, async_save=True)
    mgr.save(5, {"x": jnp.arange(10)})
    mgr.wait()
    assert mgr.latest_step() == 5
    out = mgr.restore({"x": jnp.zeros(10, jnp.int32)})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(10))


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint restores onto a different device layout (here: the
    1-device mesh with explicit shardings) — the elastic-scaling path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32).reshape(4, 2)}
    save(str(tmp_path), 3, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = restore(str(tmp_path), tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_manifest_contents(tmp_path):
    save(str(tmp_path), 11, {"x": jnp.zeros((3, 3), jnp.bfloat16)})
    with open(tmp_path / "step_00000011" / "manifest.json") as f:
        man = json.load(f)
    assert man["step"] == 11
    (leaf,) = man["leaves"].values()
    assert leaf["shape"] == [3, 3] and leaf["dtype"] == "bfloat16"
