import os
import sys

# tests run single-device (the 512-device override is dryrun.py-only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
