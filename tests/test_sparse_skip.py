"""Differential grid: digit-plane sparsity skip == dense, bit-exact.

The v4 pack attaches a per-(split, array tile, column) occupancy map
(``w_occ``) and the deploy kernels skip the MACs of unoccupied planes
(DESIGN.md §14). The contract is *bit*-exactness — not tolerance — with
the dense path: the sparse kernel bodies run the verbatim dense
expression for any block holding at least one occupied column, so XLA
cannot re-fuse the accumulate differently, and under the sign ADC
(psum_bits == 1) fully-skipped blocks fold in the exact compensation
term the dense path would have produced from an all-zero psum.

Every case compares ``deploy`` forward WITH the occupancy map against
the identical packed params WITHOUT it (occ=None falls back to the
pre-v4 dense kernel), over granularity x psum_bits x pack_dtype x
{linear, conv stride/padding} x variation-key on/off, plus adversarial
all-zero-plane and all-sign-plane weight constructions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import CIMConfig, Granularity

F32 = jnp.float32


def _cfg(mode="deploy", **kw):
    base = dict(enabled=True, mode=mode, weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=4, array_rows=32, array_cols=32,
                pack_dtype="int4")
    base.update(kw)
    return CIMConfig(**base)


def _zero_band(w, row_slice, col_slice):
    """Structurally dead region: zero weights in [row_slice, col_slice]
    produce all-zero digit planes for the covered (tile, column) pairs
    on every bit split."""
    return w.at[row_slice, col_slice].set(0.0)


def _pack_linear_with_dead_planes(cfg, k=96, n=40, seed=0):
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1), (6, k)))
    p = api.init_linear(jax.random.PRNGKey(seed), k, n, cfg)
    p = api.calibrate_linear(x, p, cfg)
    # kill tile 0 for columns 8..24 and tile 2 entirely (rows 64..96)
    w = _zero_band(p["w"], slice(0, cfg.array_rows), slice(8, 24))
    w = _zero_band(w, slice(64, 96), slice(None))
    p = dict(p, w=w)
    packed = api.pack_linear(p, cfg)
    occ = np.asarray(packed["w_occ"])
    assert occ.min() == 0 and occ.max() == 1, "construction must leave " \
        "both occupied and dead planes, or the skip path is untested"
    return p, packed, x


def _pack_conv_with_dead_planes(cfg, kh=3, kw=3, c_in=12, c_out=20, seed=0):
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (2, 9, 9, c_in)))
    p = api.init_conv(jax.random.PRNGKey(seed), kh, kw, c_in, c_out, cfg)
    p = api.calibrate_conv(x, p, cfg)
    # w is HWIO: dead (tile, column) pairs = whole input-channel slices
    # zeroed for a column band (tile membership is c // c_per_array)
    w = p["w"].at[:, :, :4, 5:14].set(0.0)
    w = w.at[:, :, :, 17].set(0.0)          # one fully dead output column
    p = dict(p, w=w)
    packed = api.pack_conv(p, cfg)
    occ = np.asarray(packed["w_occ"])
    assert occ.min() == 0 and occ.max() == 1
    return p, packed, x


def _dense(packed):
    d = dict(packed)
    d.pop("w_occ")
    return d


def _keys(with_variation):
    return (jax.random.PRNGKey(3), 0.05) if with_variation else (None, None)


# ---------------------------------------------------------------------------
# linear grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("granularity", [Granularity.COLUMN,
                                         Granularity.ARRAY])
@pytest.mark.parametrize("psum_bits", [1, 4])
@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
@pytest.mark.parametrize("variation", [False, True])
def test_linear_sparse_skip_bit_exact(granularity, psum_bits, pack_dtype,
                                      variation):
    cfg = _cfg(psum_bits=psum_bits, pack_dtype=pack_dtype,
               weight_granularity=granularity, psum_granularity=granularity)
    _, packed, x = _pack_linear_with_dead_planes(cfg)
    vk, vs = _keys(variation)
    y_sparse = api.linear(x, packed, cfg, variation_key=vk,
                          variation_std=vs, compute_dtype=F32)
    y_dense = api.linear(x, _dense(packed), cfg, variation_key=vk,
                         variation_std=vs, compute_dtype=F32)
    np.testing.assert_array_equal(np.asarray(y_sparse), np.asarray(y_dense))


@pytest.mark.parametrize("psum_bits", [1, 4])
def test_linear_sparse_matches_oracle(psum_bits):
    """Sparse kernel == packed jnp oracle (which ignores occ) within the
    repo's kernel arbitration tolerance — the skip is storage-level, not
    a numerics change."""
    cfg = _cfg(psum_bits=psum_bits)
    _, packed, x = _pack_linear_with_dead_planes(cfg)
    y_k = api.linear(x, packed, cfg, compute_dtype=F32)
    y_o = api.linear(x, packed, cfg.replace(use_kernel=False),
                     compute_dtype=F32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_o),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# conv grid (stride / padding / odd c_per_array)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID")])
@pytest.mark.parametrize("psum_bits", [1, 4])
@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_conv_sparse_skip_bit_exact(stride, padding, psum_bits, pack_dtype):
    # array_rows=36 with a 3x3 kernel -> c_per_array=4 (even): int4
    # planes nibble-pack, so this grid covers skip-on-packed-bytes
    cfg = _cfg(psum_bits=psum_bits, pack_dtype=pack_dtype, array_rows=36)
    _, packed, x = _pack_conv_with_dead_planes(cfg)
    y_sparse = api.conv2d(x, packed, cfg, stride=stride, padding=padding,
                          compute_dtype=F32)
    y_dense = api.conv2d(x, _dense(packed), cfg, stride=stride,
                         padding=padding, compute_dtype=F32)
    np.testing.assert_array_equal(np.asarray(y_sparse), np.asarray(y_dense))


@pytest.mark.parametrize("variation", [False, True])
def test_conv_sparse_skip_odd_cpa_int4(variation):
    """array_rows=32 with 3x3 taps -> c_per_array=3 (odd): int4 stays
    dense storage (no nibble pack), but the occupancy skip still applies;
    variation noise must not invalidate the clean-digit occupancy map."""
    cfg = _cfg(psum_bits=1, array_rows=32)
    p, packed, x = _pack_conv_with_dead_planes(cfg)
    assert str(np.asarray(packed["w_digits"]).dtype) == "int4"
    vk, vs = _keys(variation)
    y_sparse = api.conv2d(x, packed, cfg, variation_key=vk,
                          variation_std=vs, compute_dtype=F32)
    y_dense = api.conv2d(x, _dense(packed), cfg, variation_key=vk,
                         variation_std=vs, compute_dtype=F32)
    np.testing.assert_array_equal(np.asarray(y_sparse), np.asarray(y_dense))


# ---------------------------------------------------------------------------
# adc_free backend rides the same occ plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make",
                         [_pack_linear_with_dead_planes,
                          _pack_conv_with_dead_planes],
                         ids=["linear", "conv"])
def test_adc_free_sparse_skip_bit_exact(make):
    cfg = _cfg("adc_free", array_rows=36)
    _, packed, x = make(cfg)
    fwd = api.linear if x.ndim == 2 else api.conv2d
    y_sparse = fwd(x, packed, cfg, compute_dtype=F32)
    y_dense = fwd(x, _dense(packed), cfg, compute_dtype=F32)
    np.testing.assert_array_equal(np.asarray(y_sparse), np.asarray(y_dense))


# ---------------------------------------------------------------------------
# adversarial constructions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("psum_bits", [1, 4])
def test_all_zero_weight_every_plane_skipped(psum_bits):
    """w == 0: every plane is dead, every kernel block takes the skip
    branch. Under the sign ADC the output is NONZERO (psum 0 quantizes
    to +1 per column -> the compensation term), and sparse must
    reproduce it bit-exactly."""
    cfg = _cfg(psum_bits=psum_bits)
    k, n = 96, 40
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (6, k)))
    p = api.init_linear(jax.random.PRNGKey(0), k, n, cfg)
    p = api.calibrate_linear(x, p, cfg)
    p = dict(p, w=jnp.zeros_like(p["w"]))
    packed = api.pack_linear(p, cfg)
    assert not np.asarray(packed["w_occ"]).any()
    y_sparse = api.linear(x, packed, cfg, compute_dtype=F32)
    y_dense = api.linear(x, _dense(packed), cfg, compute_dtype=F32)
    np.testing.assert_array_equal(np.asarray(y_sparse), np.asarray(y_dense))
    if psum_bits == 1:
        assert np.abs(np.asarray(y_dense)).max() > 0, \
            "sign-ADC zero-plane output must be nonzero — the " \
            "compensation term is what the skip has to reproduce"
    else:
        np.testing.assert_array_equal(np.asarray(y_dense),
                                      np.zeros_like(y_dense))


@pytest.mark.parametrize("psum_bits", [1, 4])
def test_all_sign_plane_never_skipped(psum_bits):
    """w at negative full scale: the sign (MSB) digit plane saturates
    everywhere — those planes are maximally occupied and must not skip —
    while lower digit planes of columns that quantize exactly to -8
    (digits [-2, 0]) go dead, and a zeroed column band adds fully dead
    columns. The mix of live-sign/dead-LSB planes in one layer is the
    adversarial part."""
    cfg = _cfg(psum_bits=psum_bits)
    k, n = 96, 40
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (6, k)))
    p = api.init_linear(jax.random.PRNGKey(0), k, n, cfg)
    p = api.calibrate_linear(x, p, cfg)
    w = -jnp.max(jnp.abs(p["w"])) * jnp.ones_like(p["w"])
    w = _zero_band(w, slice(None), slice(30, 40))     # dead columns 30..39
    p = dict(p, w=w)
    packed = api.pack_linear(p, cfg)
    occ = np.asarray(packed["w_occ"])
    # every live column has its sign plane occupied in some split; the
    # zeroed band is dead across all splits; and at least one live
    # column carries a dead lower-digit plane (the skip under test)
    assert occ[..., :30].any(axis=0).all()
    assert not occ[..., 30:].any()
    assert not occ[..., :30].all()
    y_sparse = api.linear(x, packed, cfg, compute_dtype=F32)
    y_dense = api.linear(x, _dense(packed), cfg, compute_dtype=F32)
    np.testing.assert_array_equal(np.asarray(y_sparse), np.asarray(y_dense))
