"""Property layer for the packed-nibble digit planes (DESIGN.md §14).

Pins the storage-level contract the deploy kernels rely on:

  * pack/unpack round-trips exactly over the FULL int4 range — including
    -8, which a sign-magnitude reading of the nibble would lose;
  * odd packed-axis counts refuse to pack (the even-only rule that keeps
    the logical shape reconstructible without metadata);
  * ragged column counts survive the sharded path's ``pad_cols`` at
    packed byte width (shard boundaries are byte-aligned because the
    column axis is never the packed axis);
  * dtypes are stable under jit — a nibble plane never silently widens;
  * the conv flattened view unpacks with ``groups=kh*kw`` to exactly the
    canonical 6-D pack's row order.

Deterministic cases run everywhere; the hypothesis fuzz versions ride
the optional-dependency shim (``_hypothesis_compat``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.nibble import (NIBBLE_DTYPE, can_pack_nibbles,
                               is_nibble_packed, occupancy_map, pack_nibbles,
                               stored_rows, unpack_nibbles)
from repro.kernels.ops import pad_cols


def _planes(rng, shape):
    return rng.integers(-8, 8, size=shape).astype(np.int8)


# ---------------------------------------------------------------------------
# round trip
# ---------------------------------------------------------------------------

def test_roundtrip_full_int4_range_including_minus_8():
    """Every (lo, hi) nibble pair in [-8, 7]^2 survives the byte."""
    lo, hi = np.meshgrid(np.arange(-8, 8), np.arange(-8, 8))
    planes = np.stack([lo.reshape(-1), hi.reshape(-1)]).astype(np.int8)
    packed = pack_nibbles(jnp.asarray(planes))                # (1, 256)
    assert packed.shape == (1, 256) and packed.dtype == NIBBLE_DTYPE
    out = np.asarray(unpack_nibbles(packed))
    assert out.dtype == np.int8
    np.testing.assert_array_equal(out, planes)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.sampled_from([2, 4, 8, 12, 32, 64]),
    n=st.integers(1, 40),
    seed=st.integers(0, 2 ** 16),
)
def test_roundtrip_property(rows, n, seed):
    rng = np.random.default_rng(seed)
    planes = _planes(rng, (3, 2, rows, n))
    packed = pack_nibbles(jnp.asarray(planes))
    assert packed.shape == (3, 2, rows // 2, n)
    assert is_nibble_packed(packed)
    np.testing.assert_array_equal(np.asarray(unpack_nibbles(packed)), planes)


@pytest.mark.parametrize("n", [1, 7, 33])     # odd / ragged column counts
def test_roundtrip_odd_column_counts(n):
    """The packed axis is rows, never columns — any column count packs."""
    rng = np.random.default_rng(n)
    planes = _planes(rng, (2, 3, 8, n))
    packed = pack_nibbles(jnp.asarray(planes))
    assert packed.shape[-1] == n
    np.testing.assert_array_equal(np.asarray(unpack_nibbles(packed)), planes)


def test_odd_rows_refuse_to_pack():
    with pytest.raises(ValueError, match="even"):
        pack_nibbles(jnp.zeros((2, 2, 11, 4), jnp.int8))
    assert not can_pack_nibbles(11, jnp.int4)
    assert stored_rows(11, jnp.int4) == (11, jnp.int4)
    assert stored_rows(12, jnp.int4) == (6, NIBBLE_DTYPE)
    assert stored_rows(12, jnp.int8) == (12, jnp.int8)


def test_unpack_rejects_bad_groups():
    with pytest.raises(ValueError, match="groups"):
        unpack_nibbles(jnp.zeros((2, 2, 10, 4), jnp.uint8), groups=4)


# ---------------------------------------------------------------------------
# ragged shards: pad_cols at packed byte width
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,n_shards", [(33, 4), (37, 4), (5, 2), (8, 4)])
def test_pad_cols_ragged_last_shard_byte_aligned(n, n_shards):
    """Sharding pads packed uint8 planes along columns only: every shard
    boundary is byte-aligned, and the logical digits of the original
    columns are untouched."""
    rng = np.random.default_rng(n * 31 + n_shards)
    planes = _planes(rng, (2, 3, 8, n))
    packed = pack_nibbles(jnp.asarray(planes))
    s_p = jnp.ones((2, 3, n), jnp.float32)
    deq = jnp.ones((2, 3, n), jnp.float32)
    occ = occupancy_map(jnp.asarray(planes))
    d_p, sp_p, dq_p, occ_p = pad_cols(packed, s_p, deq, n_shards, occ)
    n_pad = -(-n // n_shards) * n_shards
    assert d_p.shape[-1] == sp_p.shape[-1] == dq_p.shape[-1] == n_pad
    assert occ_p.shape[-1] == n_pad
    assert d_p.dtype == NIBBLE_DTYPE                  # still packed bytes
    out = np.asarray(unpack_nibbles(d_p))
    np.testing.assert_array_equal(out[..., :n], planes)
    assert not np.any(out[..., n:])                   # dead columns: zeros
    assert not np.any(np.asarray(occ_p)[..., n:])     # dead columns skip


def test_pad_cols_without_occ_keeps_arity():
    d, sp, dq, occ = pad_cols(jnp.zeros((1, 1, 4, 6), jnp.int8),
                              jnp.ones((1, 1, 6)), jnp.ones((1, 1, 6)), 4)
    assert occ is None and d.shape[-1] == 8


# ---------------------------------------------------------------------------
# jit dtype stability
# ---------------------------------------------------------------------------

def test_dtype_stable_under_jit():
    planes = jnp.asarray(_planes(np.random.default_rng(0), (2, 2, 8, 5)))
    packed = jax.jit(pack_nibbles)(planes)
    assert packed.dtype == NIBBLE_DTYPE
    out = jax.jit(unpack_nibbles)(packed)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(planes))
    occ = jax.jit(occupancy_map)(planes)
    assert occ.dtype == jnp.uint8


# ---------------------------------------------------------------------------
# conv layout: 6-D pack == flattened unpack with groups=kh*kw
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    khw=st.sampled_from([(1, 1), (3, 3), (1, 3), (5, 5)]),
    cpa=st.sampled_from([2, 4, 14]),
    seed=st.integers(0, 2 ** 16),
)
def test_conv_groups_equivalence_property(khw, cpa, seed):
    _conv_groups_case(khw, cpa, seed)


@pytest.mark.parametrize("khw,cpa", [((3, 3), 4), ((1, 1), 2), ((5, 5), 14)])
def test_conv_groups_equivalence(khw, cpa):
    _conv_groups_case(khw, cpa, seed=7)


def _conv_groups_case(khw, cpa, seed):
    """The kernels see the 6-D conv plane FLATTENED to (S, kt,
    kh*kw*cpa_p, C_out); each tap is its own packed block, so unpacking
    the flat view with groups=kh*kw must restore exactly the flattened
    canonical (groups=1 on the 6-D layout) digits."""
    kh, kw = khw
    rng = np.random.default_rng(seed)
    d6 = _planes(rng, (2, 2, kh, kw, cpa, 9))
    packed6 = pack_nibbles(jnp.asarray(d6))           # canonical: cpa axis
    flat_p = packed6.reshape(2, 2, kh * kw * (cpa // 2), 9)
    out = np.asarray(unpack_nibbles(flat_p, groups=kh * kw))
    np.testing.assert_array_equal(out, d6.reshape(2, 2, kh * kw * cpa, 9))


# ---------------------------------------------------------------------------
# occupancy maps
# ---------------------------------------------------------------------------

def test_occupancy_map_linear_and_conv():
    planes = np.zeros((2, 3, 4, 5), np.int8)
    planes[0, 1, 2, 3] = -1
    occ = np.asarray(occupancy_map(jnp.asarray(planes)))
    assert occ.shape == (2, 3, 5) and occ.dtype == np.uint8
    assert occ.sum() == 1 and occ[0, 1, 3] == 1

    d6 = np.zeros((2, 2, 3, 3, 4, 5), np.int8)
    d6[1, 0, 2, 2, 0, 4] = 3
    occ6 = np.asarray(occupancy_map(jnp.asarray(d6), conv=True))
    assert occ6.shape == (2, 2, 5)
    assert occ6.sum() == 1 and occ6[1, 0, 4] == 1


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_occupancy_invariant_under_packing(seed):
    """occ computed on logical planes equals occ implied by the packed
    bytes: a packed row byte is 0 iff both of its digits are 0."""
    rng = np.random.default_rng(seed)
    planes = _planes(rng, (2, 2, 8, 11))
    planes[:, :, :, rng.integers(0, 11)] = 0          # force a dead column
    occ = np.asarray(occupancy_map(jnp.asarray(planes)))
    packed = np.asarray(pack_nibbles(jnp.asarray(planes)))
    np.testing.assert_array_equal(occ, (packed != 0).any(axis=-2))
