"""Hardware-style backends (DESIGN.md §13): registry error paths, the
``adc_free`` and ``binary`` backends' pack/forward/kernel/artifact
contracts, variation threading, the batched MoE expert kernel, and
property tests (hypothesis; skip cleanly when not installed).

Model-level parity across the zoo is ``zoo``-marked (CI's zoo job); the
sharded bit-exactness cases skip below 4 devices (CI's sharded job
forces a 4-device host).
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st
from repro import api
from repro.api import (Backend, CIMConfig, DeployArtifact, QuantConv2d,
                       QuantLinear, Variation, get_backend, register_backend,
                       registered_backends)
from repro.api import backends as backend_registry

BUILTIN_STYLES = ("off", "emulate", "deploy", "ref", "adc_free", "binary")

# the repo's kernel-vs-oracle arbitration tolerance (tests/test_kernels.py)
KTOL = dict(rtol=1e-5, atol=1e-4)


def _cfg(mode="deploy", **kw):
    base = dict(enabled=True, mode=mode, weight_bits=4, cell_bits=2,
                act_bits=6, psum_bits=6, array_rows=32, array_cols=32)
    base.update(kw)
    return CIMConfig(**base)


def _linear_packed(mode, k=40, n=24, batch=6, seed=0, **kw):
    """init -> calibrate -> pack a linear layer for ``mode``'s backend."""
    cfg = _cfg(mode, **kw)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (batch, k)))
    params = api.init_linear(jax.random.PRNGKey(seed), k, n, cfg)
    params = api.calibrate_linear(x, params, cfg)
    return cfg, params, api.pack_linear(params, cfg), x


def _conv_packed(mode, c_in=6, c_out=10, seed=0, **kw):
    cfg = _cfg(mode, **kw)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (2, 8, 8, c_in)))
    params = api.init_conv(jax.random.PRNGKey(seed), 3, 3, c_in, c_out, cfg)
    params = api.calibrate_conv(x, params, cfg)
    return cfg, params, api.pack_conv(params, cfg), x


# -- registry (satellite: collision + error paths) --------------------------

def test_builtin_styles_registered():
    assert set(BUILTIN_STYLES) <= set(registered_backends())
    for name in ("adc_free", "binary"):
        b = get_backend(name)
        assert b.packed, f"{name} must consume packed planes"
    assert get_backend("binary").plane_bits == (1, 1)
    assert get_backend("binary").pack_linear is not None
    # adc_free consumes the standard deploy pack (no packer override)
    assert get_backend("adc_free").pack_linear is None


def test_register_backend_collision_raises_unless_replace():
    dummy = dataclasses.replace(get_backend("deploy"),
                                name="test-dummy-style",
                                description="collision probe")
    register_backend(dummy)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_backend(dummy)
        # same name, replace=True: allowed, and the new object wins
        dummy2 = dataclasses.replace(dummy, description="v2")
        assert register_backend(dummy2, replace=True) is dummy2
        assert get_backend("test-dummy-style").description == "v2"
        # registration made the name a valid CIMConfig.mode
        assert _cfg("test-dummy-style").mode == "test-dummy-style"
    finally:
        del backend_registry._REGISTRY["test-dummy-style"]
        backend_registry._lin._KNOWN_MODES.discard("test-dummy-style")


def test_unknown_mode_rejected_at_config_time():
    with pytest.raises(ValueError, match="unknown CIM mode"):
        _cfg("hcim-v9")
    # the error names what IS registered, so the fix is discoverable
    with pytest.raises(ValueError, match="binary"):
        _cfg("hcim-v9")


def test_artifact_for_unregistered_backend_fails_clearly(tmp_path):
    """An artifact packed by a session with backend X, loaded in a session
    that never registered X: a ValueError naming the backend and the
    remedy — not a KeyError from the registry internals."""
    cfg = _cfg("deploy")
    h = QuantLinear(40, 24, cfg).init(jax.random.PRNGKey(0))
    h.calibrate(jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1),
                                              (4, 40))))
    path = str(tmp_path / "art")
    h.pack().save(path)

    jpath = os.path.join(path, "artifact.json")
    with open(jpath) as f:
        head = json.load(f)
    head["backend"] = head["config"]["mode"] = "tricium-sram"
    with open(jpath, "w") as f:
        json.dump(head, f)

    with pytest.raises(ValueError) as ei:
        DeployArtifact.load(path)
    msg = str(ei.value)
    assert "tricium-sram" in msg
    assert "register_backend" in msg
    assert "binary" in msg          # lists registered backends


def test_artifact_layout_v3_stamps_backend(tmp_path):
    cfg = _cfg("binary")
    h = QuantLinear(40, 24, cfg).init(jax.random.PRNGKey(0))
    h.calibrate(jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1),
                                              (4, 40))))
    path = str(tmp_path / "art")
    h.pack().save(path)
    with open(os.path.join(path, "artifact.json")) as f:
        head = json.load(f)
    assert head["layout_version"] == api.ARTIFACT_LAYOUT_VERSION >= 3
    assert head["backend"] == "binary"


# -- adc_free ---------------------------------------------------------------

def test_adc_free_is_transparent_adc_deploy():
    """Digital accumulation == the ADC pipeline with the quantizer made
    transparent (unit column scales, clip range far beyond any psum):
    bit-exact, both on the oracle arithmetic."""
    cfg, params, packed, x = _linear_packed("adc_free", use_kernel=False)
    y = api.linear(x, packed, cfg, compute_dtype=jnp.float32)

    wide = cfg.replace(mode="deploy", psum_bits=20)
    transparent = dict(packed, s_p=jnp.ones_like(packed["s_p"]))
    y_ref = api.linear(x, transparent, wide, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_adc_free_kernel_matches_oracle(pack_dtype):
    cfg, _, packed, x = _linear_packed("adc_free", pack_dtype=pack_dtype,
                                       use_kernel=True)
    y_k = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
    y_r = api.linear(x, packed, cfg.replace(use_kernel=False),
                     compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **KTOL)


def test_adc_free_conv_kernel_matches_oracle():
    cfg, _, packed, x = _conv_packed("adc_free", use_kernel=True)
    y_k = api.conv2d(x, packed, cfg, compute_dtype=jnp.float32)
    y_r = api.conv2d(x, packed, cfg.replace(use_kernel=False),
                     compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **KTOL)


def test_adc_free_beats_narrow_adc():
    """No ADC means no psum quantization error: at a deliberately starved
    ADC resolution the deploy error must exceed adc_free's."""
    def rel_err(mode, psum_bits):
        cfg, params, packed, x = _linear_packed(mode, psum_bits=psum_bits,
                                                use_kernel=False)
        y = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
        y_fp = x @ params["w"].astype(jnp.float32)
        return float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))

    assert rel_err("adc_free", 2) < rel_err("deploy", 2)
    # psum_bits is inert for adc_free accuracy
    assert rel_err("adc_free", 2) == pytest.approx(rel_err("adc_free", 8))


# -- binary -----------------------------------------------------------------

def test_binary_pack_geometry_and_alpha():
    """S=1 sign planes: digits in {-1, 0, +1}, padded rows dead, and the
    per-column scale is alpha = mean |w| over the REAL rows of each tile
    (BWN, XNOR-Net eq. 6) at full column granularity."""
    cfg, params, packed, _ = _linear_packed("binary", k=40, n=24)
    d = packed["w_digits"]
    assert d.shape == (1, 2, 32, 24)          # S=1, kt=2 (40 over 32 rows)
    dv = np.asarray(d.astype(jnp.int32))
    assert set(np.unique(dv)) <= {-1, 0, 1}
    # rows 8.. of the second tile are padding (40 = 32 + 8): dead cells
    assert np.all(dv[0, 1, 8:, :] == 0)
    assert np.all(dv[0, 0] != 0)              # sign of a continuous weight

    w = np.asarray(params["w"])
    alpha = np.asarray(packed["s_w"])         # (kt, n) full column scales
    np.testing.assert_allclose(alpha[0], np.abs(w[:32]).mean(0), rtol=1e-5)
    np.testing.assert_allclose(alpha[1], np.abs(w[32:]).mean(0), rtol=1e-5)


def test_binary_forward_error_in_bwn_regime():
    """1-bit weights cannot be bit-faithful; the expected relative error
    for Gaussian weights is sqrt(1 - 2/pi) ~ 0.6. Check the forward is
    finite and lands in that regime (well below 1, well above fp noise)."""
    cfg, params, packed, x = _linear_packed("binary", k=128, n=64, batch=32,
                                            use_kernel=False)
    y = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
    y_fp = x @ params["w"].astype(jnp.float32)
    assert np.all(np.isfinite(np.asarray(y)))
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert 0.2 < rel < 0.95


@pytest.mark.parametrize("pack_dtype", ["int8", "int4"])
def test_binary_kernel_matches_oracle(pack_dtype):
    cfg, _, packed, x = _linear_packed("binary", pack_dtype=pack_dtype,
                                       use_kernel=True)
    y_k = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
    y_r = api.linear(x, packed, cfg.replace(use_kernel=False),
                     compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **KTOL)


def test_binary_conv_pack_and_kernel():
    cfg, params, packed, x = _conv_packed("binary", use_kernel=True)
    d = packed["w_digits"]
    assert d.ndim == 6 and d.shape[0] == 1    # (S=1, kt, kh, kw, cpa, co)
    y_k = api.conv2d(x, packed, cfg, compute_dtype=jnp.float32)
    y_r = api.conv2d(x, packed, cfg.replace(use_kernel=False),
                     compute_dtype=jnp.float32)
    assert np.all(np.isfinite(np.asarray(y_k)))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **KTOL)


def test_binary_measured_psum_scale_improves_or_matches():
    """binary_calibrate_psum_scale replaces the analytic s_p with a
    measured one; the resulting forward must stay finite and the scale
    must reflect the actual psum distribution (positive, non-degenerate)."""
    from repro.backends import binary_calibrate_psum_scale
    cfg, params, packed, x = _linear_packed("binary", use_kernel=False)
    cal = binary_calibrate_psum_scale(packed, cfg, x)
    assert cal["s_p"].shape == packed["s_p"].shape
    assert np.all(np.asarray(cal["s_p"]) > 0)
    y = api.linear(x, cal, cfg, compute_dtype=jnp.float32)
    assert np.all(np.isfinite(np.asarray(y)))


# -- pack -> save -> load -> serve round trips ------------------------------

@pytest.mark.parametrize("mode", ["adc_free", "binary"])
def test_linear_artifact_roundtrip_serves_bit_exact(mode, tmp_path):
    cfg = _cfg(mode)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (4, 40)))
    h = QuantLinear(40, 24, cfg).init(jax.random.PRNGKey(0)).calibrate(x)
    art = h.pack()
    assert art.config.mode == mode
    path = str(tmp_path / "art")
    art.save(path)
    loaded = DeployArtifact.load(path)
    assert loaded.config == art.config
    for a, b in zip(jax.tree.leaves(art.params), jax.tree.leaves(loaded.params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    y0 = QuantLinear.from_artifact(art)(x)
    y1 = QuantLinear.from_artifact(loaded)(x)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


@pytest.mark.parametrize("mode", ["adc_free", "binary"])
def test_conv_artifact_roundtrip_serves_bit_exact(mode, tmp_path):
    cfg = _cfg(mode)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 6)))
    h = QuantConv2d(3, 3, 6, 10, cfg).init(jax.random.PRNGKey(0)).calibrate(x)
    path = str(tmp_path / "art")
    h.pack().save(path)
    served = QuantConv2d.from_artifact(DeployArtifact.load(path))
    y0, y1 = QuantConv2d.from_artifact(h.pack())(x), served(x)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


# -- variation + Monte-Carlo robustness harness -----------------------------

@pytest.mark.parametrize("mode", ["adc_free", "binary"])
def test_variation_threading(mode):
    """Per-call device variation on the new backends: deterministic under
    a fixed key, off at sigma=0, and actually perturbing at sigma>0."""
    cfg, _, packed, x = _linear_packed(mode, use_kernel=False)
    served = QuantLinear.from_artifact(
        DeployArtifact(kind="linear", config=cfg, params=packed,
                       meta={"k": 40, "n": 24, "col_shard": ["."]}))
    clean = served(x)
    var = Variation(jax.random.PRNGKey(7), 0.2)
    noisy = served(x, variation=var)
    noisy2 = served(x, variation=var)
    np.testing.assert_array_equal(np.asarray(noisy), np.asarray(noisy2))
    assert not np.array_equal(np.asarray(clean), np.asarray(noisy))
    zero = served(x, variation=Variation(jax.random.PRNGKey(7), 0.0))
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(zero))


@pytest.mark.parametrize("mode", ["adc_free", "binary"])
def test_monte_carlo_harness_covers_new_backends(mode):
    from repro.eval.robustness import monte_carlo_linear_error
    cfg, _, packed, x = _linear_packed(mode, use_kernel=False)
    sigmas = (0.05, 0.2)
    errs = np.asarray(monte_carlo_linear_error(
        packed, cfg, x, key=jax.random.PRNGKey(3), sigmas=sigmas,
        n_samples=3))
    assert errs.shape == (len(sigmas), 3)
    assert np.all(np.isfinite(errs)) and np.all(errs >= 0)
    # more cell noise, more error (monotone in the mean)
    assert errs[1].mean() > errs[0].mean()


# -- batched MoE expert kernel (satellite: lax.map replacement) -------------

def _mk_experts(e, m, kt, rows, n, s, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    a = jnp.round(jax.random.normal(ks[0], (e, m, kt, rows)) * 4)
    d = jax.random.randint(ks[1], (e, s, kt, rows, n), -3, 4).astype(jnp.int8)
    s_p = jax.random.uniform(ks[2], (e, s, kt, n), minval=0.5, maxval=20.0)
    deq = jax.random.uniform(ks[3], (e, s, kt, n), minval=0.01, maxval=0.1)
    return a, d, s_p, deq


@pytest.mark.parametrize("e,m,kt,rows,n,s", [
    (2, 8, 1, 32, 16, 1),
    (4, 16, 2, 32, 24, 2),
    (3, 5, 2, 33, 7, 2),      # awkward/non-aligned
])
@pytest.mark.parametrize("psum_bits", [4, 8])
def test_experts_kernel_matches_per_expert_loop(e, m, kt, rows, n, s,
                                                psum_bits):
    """The batched (E, ...) expert kernel is bit-exact with dispatching
    ``cim_matmul`` once per expert — the contract that lets the MoE
    batched path replace ``lax.map`` without moving any logits."""
    from repro.kernels import ops
    a, d, s_p, deq = _mk_experts(e, m, kt, rows, n, s)
    out_b = ops.cim_matmul_experts(a, d, s_p, deq, psum_bits=psum_bits)
    out_l = jnp.stack([
        ops.cim_matmul(a[i], d[i], s_p[i], deq[i], psum_bits=psum_bits,
                       use_kernel=True)
        for i in range(e)])
    np.testing.assert_array_equal(np.asarray(out_b), np.asarray(out_l))


def test_experts_kernel_int4_planes():
    from repro.kernels import ops
    a, d, s_p, deq = _mk_experts(2, 8, 2, 32, 16, 2)
    d4 = d.astype(jnp.int4)
    out4 = ops.cim_matmul_experts(a, d4, s_p, deq, psum_bits=6)
    out8 = ops.cim_matmul_experts(a, d, s_p, deq, psum_bits=6)
    np.testing.assert_array_equal(np.asarray(out4), np.asarray(out8))


def test_batched_expert_dispatch_matches_lax_map():
    """Force the two model-layer MoE dispatch paths (batched kernel vs
    serial lax.map) onto the same packed bank and compare bit-exactly."""
    from repro.models import layers as L

    cfg_cim = _cfg("deploy")
    e, k, n, toks = 3, 40, 24, 5
    banks = {"w": jax.random.normal(jax.random.PRNGKey(0), (e, k, n)) * 0.1}

    def pack_expert(w):
        p = api.init_linear(jax.random.PRNGKey(1), k, n, cfg_cim)
        p = dict(p, w=w)
        p = api.calibrate_linear(
            jax.nn.relu(jax.random.normal(jax.random.PRNGKey(2), (4, k))),
            p, cfg_cim)
        return api.pack_linear(p, cfg_cim)

    packed = jax.vmap(pack_expert)(banks["w"])
    p = {"up_digits" if kk == "w_digits" else f"up_{kk}": v
         for kk, v in packed.items() if kk != "k_logical"}
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(3), (e, toks, k)))

    cfg = type("Cfg", (), {"cim": cfg_cim, "compute_dtype": "float32"})()
    assert L._batched_experts_ok(p, "up", cfg)
    y_batched = L._batched_expert_matmul(p, "up", x, cfg)

    tiny = dataclasses.replace(cfg_cim)   # same cfg, gate forced off below
    old = L._EXPERT_BANK_BATCH_BYTES
    try:
        L._EXPERT_BANK_BATCH_BYTES = 0
        cfg_map = type("Cfg", (), {"cim": tiny, "compute_dtype": "float32"})()
        assert not L._batched_experts_ok(p, "up", cfg_map)
        y_map = L._expert_matmul(p, "up", x, cfg_map)
    finally:
        L._EXPERT_BANK_BATCH_BYTES = old
    np.testing.assert_array_equal(np.asarray(y_batched), np.asarray(y_map))


# -- property tests (hypothesis; skip without it) ---------------------------

@given(k=st.integers(min_value=3, max_value=70),
       n=st.integers(min_value=2, max_value=40),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_prop_adc_free_equals_unquantized_psum_sum(k, n, seed):
    """Property: adc_free digital accumulation == the emulate psum sum in
    the psum_bits -> infinity limit (transparent ADC), for any layer
    geometry. Bit-exact on the shared oracle arithmetic."""
    cfg, _, packed, x = _linear_packed("adc_free", k=k, n=n, seed=seed,
                                       use_kernel=False)
    y = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
    wide = cfg.replace(mode="deploy", psum_bits=24)
    y_ref = api.linear(x, dict(packed, s_p=jnp.ones_like(packed["s_p"])),
                       wide, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@given(k=st.integers(min_value=3, max_value=70),
       n=st.integers(min_value=2, max_value=40),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_prop_binary_digits_are_signs(k, n, seed):
    """Property: the binary pack stores exactly sign(w) on real rows and
    0 on padding, with strictly positive column scales."""
    cfg, params, packed, _ = _linear_packed("binary", k=k, n=n, seed=seed)
    t = backend_registry.plane_tiling(cfg, k, n)
    d = np.asarray(packed["w_digits"].astype(jnp.int32))
    w = np.asarray(params["w"])
    flat = d[0].reshape(t.k_tiles * t.array_rows, n)
    np.testing.assert_array_equal(flat[:k], np.where(w >= 0, 1, -1))
    assert np.all(flat[k:] == 0)
    assert np.all(np.asarray(packed["s_w"]) > 0)


def test_adc_free_transparency_fixed_seeds():
    """Deterministic stand-in for the property above so the invariant is
    exercised even where hypothesis isn't installed."""
    for k, n, seed in ((3, 2, 0), (33, 17, 1), (64, 40, 2), (70, 5, 3)):
        cfg, _, packed, x = _linear_packed("adc_free", k=k, n=n, seed=seed,
                                           use_kernel=False)
        y = api.linear(x, packed, cfg, compute_dtype=jnp.float32)
        wide = cfg.replace(mode="deploy", psum_bits=24)
        y_ref = api.linear(x, dict(packed, s_p=jnp.ones_like(packed["s_p"])),
                           wide, compute_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


# -- sharded bit-exactness (CI sharded job: 4 forced devices) ---------------

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")


@needs4
@pytest.mark.parametrize("mode", ["adc_free", "binary"])
@pytest.mark.parametrize("n", [24, 22])   # divisible and ragged over 4
def test_sharded_bit_exact_with_shared_variation_key(mode, n):
    from repro.nn.module import set_activation_rules
    cfg = _cfg(mode)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (6, 40)))
    h = QuantLinear(40, n, cfg).init(jax.random.PRNGKey(0)).calibrate(x)
    served = QuantLinear.from_artifact(h.pack())
    var = Variation(jax.random.PRNGKey(7), 0.2)

    y1, y1v = served(x), served(x, variation=var)
    mesh = jax.make_mesh((4,), ("model",))
    set_activation_rules({}, mesh)
    try:
        y4, y4v = served(x), served(x, variation=var)
    finally:
        set_activation_rules(None, None)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y4))
    np.testing.assert_array_equal(np.asarray(y1v), np.asarray(y4v))
    assert not np.array_equal(np.asarray(y1), np.asarray(y1v))


# -- model-level parity (zoo job) -------------------------------------------

ZOO_CIM = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                    act_bits=8, psum_bits=6, array_rows=32, array_cols=32)


@pytest.mark.zoo
@pytest.mark.parametrize("arch", ["llama3-8b", "whisper-small"])
@pytest.mark.parametrize("mode", ["adc_free", "binary"])
def test_model_parity_new_backends(arch, mode, tmp_path):
    """Acceptance: adc_free and binary pack -> save -> load -> serve a
    transformer (llama3) and a conv-frontend model (whisper). adc_free's
    emulate counterpart is emulate WITHOUT psum fake-quant (digital
    accumulation is the psum_bits -> infinity limit, so comparing against
    the quantized emulate would just measure the ADC error it removes);
    binary is 1-bit-lossy, so its gate is kernel-vs-oracle parity plus
    finiteness."""
    from repro.configs.registry import get_config
    from repro.models.registry import frontend_input_shape, get_model
    from repro.nn import init_params

    cfg = get_config(arch, reduced=True, cim=ZOO_CIM).replace(
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    fshape = frontend_input_shape(cfg, 2)
    extra = (None if fshape is None
             else jax.random.normal(jax.random.PRNGKey(2), fshape) * 0.1)

    art = api.model_artifact(params, ZOO_CIM.replace(mode=mode))
    path = str(tmp_path / "artifact")
    art.save(path)
    loaded = DeployArtifact.load(path)
    assert loaded.config.mode == mode
    for a, b in zip(jax.tree.leaves(art.params), jax.tree.leaves(loaded.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    dcfg = cfg.replace(cim=loaded.config)
    out = np.asarray(model.forward(loaded.params, tokens, dcfg, extra))
    assert np.all(np.isfinite(out))

    # kernel path vs jnp oracle: the packed planes serve identically
    ocfg = cfg.replace(cim=loaded.config.replace(use_kernel=False))
    oracle = np.asarray(model.forward(loaded.params, tokens, ocfg, extra))
    rel_ko = float(np.max(np.abs(out - oracle)) / np.max(np.abs(oracle)))
    assert rel_ko <= 1e-4, f"{arch}/{mode}: kernel vs oracle rel={rel_ko}"

    if mode == "adc_free":
        ecfg = cfg.replace(cim=ZOO_CIM.replace(psum_quant=False))
        em = np.asarray(model.forward(params, tokens, ecfg, extra))
        rel = float(np.max(np.abs(em - out)) / np.max(np.abs(em)))
        assert rel <= 1e-4, (f"{arch}/adc_free vs emulate(psum_quant=False) "
                             f"rel={rel}")
