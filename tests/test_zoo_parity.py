"""Deploy-vs-emulate parity matrix over the whole config registry.

For every entry in ``repro.configs.registry.ARCHS`` at reduced scale:
pack (``model_artifact``) -> save -> load -> forward on the fused deploy
backend vs. the emulate backend, asserting

  * logits within 5e-2 relative (the serving gate), and bit-identical
    for the entries where emulate/deploy agree exactly today (EXACT);
  * the artifact round-trips bit-exactly through disk;
  * every structured CIM node actually packed (digit-plane count ==
    ``meta["col_shard"]`` entries, and architecture-specific nodes —
    MoE expert banks, SSM scan stacks, encoder convs — are present);

plus a sharded-mesh spot-check for the two MoE entries (skipped below
4 devices; CI's ``zoo`` job forces a 4-device host).

Marked ``zoo``: excluded from tier-1 by pytest.ini, run as the dedicated
CI job via ``pytest -m zoo``.
"""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DeployArtifact, model_artifact
from repro.configs.registry import ARCHS, get_config
from repro.core.cim_linear import CIMConfig
from repro.core.nibble import stored_rows
from repro.models.registry import frontend_input_shape, get_model
from repro.nn import init_params

pytestmark = pytest.mark.zoo

B, T = 2, 8

CIM = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=8, psum_bits=6, array_rows=32, array_cols=32)

# Entries whose emulate and deploy logits are bit-identical today. The
# rest differ only at float-accumulation-order level (~1e-7 relative):
# the kernel grid, per-expert lax.map dispatch, and scan-carried layers
# reassociate the float32 dequant sums. Shrinking this set is a
# regression.
EXACT = frozenset({"llama3-8b", "granite-8b", "whisper-small"})

# tolerance for everything (EXACT entries additionally assert equality)
REL_TOL = 5e-2

MOE_ARCHS = ("moonshot-v1-16b-a3b", "deepseek-v3-671b")


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_config(arch, reduced=True, cim=CIM).replace(
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    fshape = frontend_input_shape(cfg, B)
    extra = (None if fshape is None
             else jax.random.normal(jax.random.PRNGKey(2), fshape) * 0.1)
    return cfg, model, params, tokens, extra


def _digit_keys(tree, path=()):
    """All '/'-joined paths of digit-plane leaves in a packed tree."""
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            if k.endswith("_digits"):
                out.append("/".join(path + (k,)))
            out.extend(_digit_keys(v, path + (k,)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_digit_keys(v, path + (str(i),)))
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_deploy_vs_emulate_parity(arch, tmp_path):
    cfg, model, params, tokens, extra = _setup(arch)
    em = np.asarray(model.forward(params, tokens, cfg, extra))

    art = model_artifact(params, cfg.cim, meta={"arch": arch})
    path = str(tmp_path / "artifact")
    art.save(path)
    loaded = DeployArtifact.load(path)

    # bit-exact round trip: identical structure (including leafless
    # nodes, e.g. parameter-free norms) and every leaf identical
    assert jax.tree.structure(art.params) == jax.tree.structure(loaded.params)
    flat_a = jax.tree.leaves(art.params)
    flat_l = jax.tree.leaves(loaded.params)
    assert len(flat_a) == len(flat_l)
    for a, b in zip(flat_a, flat_l):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # structural coverage: every CIM node became digit planes, and the
    # col_shard meta names exactly those nodes
    digits = _digit_keys(loaded.params)
    assert digits, f"{arch}: nothing packed"
    assert len(digits) == len(loaded.meta["col_shard"])

    dcfg = cfg.replace(cim=loaded.config)
    dp = np.asarray(model.forward(loaded.params, tokens, dcfg, extra))

    assert np.all(np.isfinite(dp))
    rel = float(np.max(np.abs(em - dp)) / np.max(np.abs(em)))
    assert rel <= REL_TOL, f"{arch}: deploy vs emulate rel={rel}"
    if arch in EXACT:
        np.testing.assert_array_equal(em, dp, err_msg=f"{arch} regressed "
                                      "from bit-exact deploy parity")


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_moe_banks_packed_per_expert(arch):
    """MoE entries: expert banks pack as per-expert stacked planes with
    per-expert column scales, and col_shard records one entry per bank."""
    cfg, model, params, tokens, extra = _setup(arch)
    art = model_artifact(params, cfg.cim)
    moe = art.params["moe_layers"]["moe"]
    L = cfg.n_layers - cfg.moe.n_dense_layers
    E = cfg.moe.n_experts
    for nm, k, n in (("wg", cfg.d_model, cfg.moe.d_ff),
                     ("wu", cfg.d_model, cfg.moe.d_ff),
                     ("wd", cfg.moe.d_ff, cfg.d_model)):
        t = cfg.cim.tiling(k, n)
        d = moe[f"{nm}_digits"]
        # v4 pack: int4 planes with an even row count store nibble-packed
        rows_s, store = stored_rows(t.array_rows, cfg.cim.store_dtype())
        assert d.shape == (L, E, t.n_split, t.k_tiles, rows_s, n)
        assert d.dtype == store
        assert moe[f"{nm}_occ"].shape == (L, E, t.n_split, t.k_tiles, n)
        assert moe[f"{nm}_s_w"].shape[:2] == (L, E)   # per-expert scales
        assert f"moe_layers/moe/{nm}" in art.meta["col_shard"]
    # the raw banks are gone; router and shared experts ride along
    assert "wg" not in moe and "router" in moe


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_moe_sharded_mesh_spot_check(arch):
    """Column-sharded expert planes serve bit-identically to one device."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (CI zoo job forces 4)")
    from jax.sharding import Mesh
    from repro.nn.module import session_mesh
    cfg, model, params, tokens, extra = _setup(arch)
    art = model_artifact(params, cfg.cim)
    dcfg = cfg.replace(cim=art.config)
    base = np.asarray(model.forward(art.params, tokens, dcfg, extra))

    mesh = Mesh(np.array(jax.devices()[:4]), ("model",))
    sharded = art.shard(mesh)
    # expert digit planes actually landed column-sharded
    d = sharded.params["moe_layers"]["moe"]["wg_digits"]
    assert len(d.sharding.device_set) == 4
    with session_mesh(mesh):
        out = np.asarray(model.forward(sharded.params, tokens, dcfg, extra))
    np.testing.assert_array_equal(base, out)


def test_ssm_scan_weights_served_packed():
    """zamba2: the mamba2 in/out projections pack as stacked 3-D planes
    (leading layer axis) and the scan forward consumes them directly."""
    cfg, model, params, tokens, extra = _setup("zamba2-2.7b")
    art = model_artifact(params, cfg.cim)
    mam = art.params["mamba_layers"]
    for nm in ("in_proj", "out_proj"):
        d = mam[nm]["w_digits"]
        assert d.ndim == 5 and d.shape[0] == cfg.n_layers
        assert f"mamba_layers/{nm}" in art.meta["col_shard"]
    # shared attention block packs unstacked (4-D planes)
    assert art.params["shared_attn"]["attn"]["wq"]["w_digits"].ndim == 4


def test_serve_whisper_example_token_parity():
    """The non-transformer serving example end to end: audio in through
    the conv deploy kernel, ServingEngine decode, and an internal assert
    that deploy-generated tokens match the emulate engine exactly."""
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    out = subprocess.run(
        [sys.executable, str(root / "examples" / "serve_whisper_cim.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "tokens match emulate exactly" in out.stdout


@pytest.mark.parametrize("arch,node_path", [
    ("whisper-small", ("frontend", "conv1")),
    ("whisper-small", ("frontend", "conv2")),
    ("llava-next-mistral-7b", ("patch_embed",)),
])
def test_encoder_convs_pack_as_conv_planes(arch, node_path):
    """Encoder convs pack into the self-describing 6-D conv-plane layout
    consumed by the fused ``cim_conv_pallas`` deploy kernel."""
    cfg, model, params, tokens, extra = _setup(arch)
    art = model_artifact(params, cfg.cim)
    node = art.params
    for k in node_path:
        node = node[k]
    assert node["w_digits"].ndim == 6       # (S, kt, kh, kw, cpa, c_out)
    assert "/".join(node_path) in art.meta["col_shard"]
