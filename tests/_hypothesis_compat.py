"""Optional-hypothesis shim: property-based tests skip (instead of
failing collection) when hypothesis isn't installed.

Usage in a test module:

    from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

When hypothesis is available these are the real objects. When it isn't,
``given`` replaces the test with a zero-arg skipped stand-in (the real
signature would otherwise look like missing pytest fixtures), ``settings``
is an identity decorator, and ``st.*`` strategy constructors return inert
placeholders. Install the real thing via requirements-dev.txt.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Inert stand-ins: strategy objects are only consumed by given()."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _Strategies()
