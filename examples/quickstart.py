"""Quickstart: the paper's column-wise CIM quantization in five minutes.

Builds a CIM-quantized linear layer, calibrates it, compares granularities,
packs it for deployment (int8 digit planes + fused scales -> the Pallas
kernel path) and verifies bit-exactness.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CIMConfig, Granularity, calibrate_cim, cim_linear,
                        init_cim_linear, pack_deploy)

K, N, BATCH = 512, 128, 32

base = CIMConfig(
    enabled=True, mode="emulate",
    weight_bits=4, cell_bits=2,       # 4b weights on two 2b cells
    act_bits=8, psum_bits=4,          # 4b ADC on every column partial sum
    array_rows=128, array_cols=128,   # CIM array geometry
    weight_granularity=Granularity.COLUMN,
    psum_granularity=Granularity.COLUMN,
)

key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, K)) * 0.5

print("== column-wise weight + partial-sum quantization (the paper) ==")
for g in (Granularity.LAYER, Granularity.ARRAY, Granularity.COLUMN):
    cfg = base.replace(weight_granularity=g, psum_granularity=g)
    params = init_cim_linear(key, K, N, cfg)
    # heterogeneous output columns — where fine granularity matters
    params["w"] = params["w"] * jnp.logspace(-1.5, 0.5, N)[None, :]
    params = calibrate_cim(x, params, cfg)
    y_q = cim_linear(x, params, cfg, compute_dtype=jnp.float32)
    y_fp = cim_linear(x, params, cfg.replace(mode="off"),
                      compute_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    t = cfg.tiling(K, N)
    print(f"  {g.value:7s}: quant rel-err {rel:.4f} | dequant muls/layer "
          f"{t.dequant_muls(g, g):5d}")

print("\n== deploy packing (int8 digit planes -> Pallas kernel) ==")
cfg = base
params = init_cim_linear(key, K, N, cfg)
params = calibrate_cim(x, params, cfg)
y_emulate = cim_linear(x, params, cfg, compute_dtype=jnp.float32)
deploy = pack_deploy(params, cfg)
y_deploy = cim_linear(x, deploy, cfg.replace(mode="deploy"),
                      compute_dtype=jnp.float32)
print(f"  emulate vs deploy max |diff|: "
      f"{float(jnp.max(jnp.abs(y_emulate - y_deploy))):.2e}  (bit-exact)")
w_bytes_bf16 = K * N * 2
w_bytes_cim = deploy["w_digits"].size  # int8 per digit plane
print(f"  weight HBM: bf16 {w_bytes_bf16/1e3:.0f} KB -> CIM int-digit "
      f"{w_bytes_cim/1e3:.0f} KB ({w_bytes_bf16/w_bytes_cim:.1f}x smaller)")
