"""Quickstart: the paper's column-wise CIM quantization in five minutes.

Walks the unified layer lifecycle (repro.api): build a CIM-quantized
linear handle, calibrate it, compare granularities, pack it into a
versioned DeployArtifact (int8 digit planes + fused scales -> the Pallas
kernel path), save/load the artifact and verify the round trip is
bit-exact across every packed backend.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DeployArtifact, QuantLinear
from repro.core import CIMConfig, Granularity

K, N, BATCH = 512, 128, 32

base = CIMConfig(
    enabled=True, mode="emulate",
    weight_bits=4, cell_bits=2,       # 4b weights on two 2b cells
    act_bits=8, psum_bits=4,          # 4b ADC on every column partial sum
    array_rows=128, array_cols=128,   # CIM array geometry
    weight_granularity=Granularity.COLUMN,
    psum_granularity=Granularity.COLUMN,
)

key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, K)) * 0.5

print("== column-wise weight + partial-sum quantization (the paper) ==")
for g in (Granularity.LAYER, Granularity.ARRAY, Granularity.COLUMN):
    cfg = base.replace(weight_granularity=g, psum_granularity=g)
    layer = QuantLinear(K, N, cfg).init(key)
    # heterogeneous output columns — where fine granularity matters
    layer.params["w"] = layer.params["w"] * jnp.logspace(-1.5, 0.5, N)[None, :]
    layer.calibrate(x)
    y_q = layer(x)
    y_fp = layer.with_backend("off")(x)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    t = cfg.tiling(K, N)
    print(f"  {g.value:7s}: quant rel-err {rel:.4f} | dequant muls/layer "
          f"{t.dequant_muls(g, g):5d}")

print("\n== lifecycle: quantize -> calibrate -> pack -> DeployArtifact ==")
layer = QuantLinear(K, N, base).init(key).calibrate(x)
y_emulate = layer(x)

artifact = layer.pack()                       # versioned deploy artifact
with tempfile.TemporaryDirectory() as d:
    artifact.save(d)                          # atomic, bit-exact on disk
    loaded = DeployArtifact.load(d)

served = QuantLinear.from_artifact(loaded)    # deploy backend (Pallas)
y_deploy = served(x)
y_ref = served.with_backend("ref")(x)         # packed jnp oracle
print(f"  emulate vs deploy max |diff|: "
      f"{float(jnp.max(jnp.abs(y_emulate - y_deploy))):.2e}  (bit-exact)")
np.testing.assert_allclose(np.asarray(y_deploy), np.asarray(y_ref),
                           rtol=1e-5, atol=1e-5)  # kernel vs jnp oracle
y_mem = QuantLinear.from_artifact(artifact)(x)   # pre-save, in memory
print(f"  layout_version={loaded.layout_version}, "
      f"backend={loaded.config.mode!r}, save->load bit-exact: "
      f"{bool(jnp.all(y_mem == y_deploy))}")
assert bool(jnp.all(y_mem == y_deploy)), "artifact round trip drifted"
w_bytes_bf16 = K * N * 2
w_bytes_cim = loaded.params["w_digits"].size  # int8 per digit plane
print(f"  weight HBM: bf16 {w_bytes_bf16/1e3:.0f} KB -> CIM int-digit "
      f"{w_bytes_cim/1e3:.0f} KB ({w_bytes_bf16/w_bytes_cim:.1f}x smaller)")
