"""Serving example: batched generation from a CIM deploy-mode model —
weights live as int8 digit planes with fused per-column dequant scales
(the memory-roofline win for decode).

  PYTHONPATH=src python examples/serve_quantized_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity as G
from repro.models.registry import get_model
from repro.nn import init_params
from repro.serve.engine import ServingEngine

cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                weight_granularity=G.COLUMN, psum_granularity=G.COLUMN,
                use_kernel=False)
cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
model = get_model(cfg)
params = init_params(model.specs(cfg), jax.random.PRNGKey(0))

B = 4
engine = ServingEngine(model, cfg, params, batch_size=B, max_len=128)
prompts = np.random.RandomState(0).randint(0, cfg.vocab, (B, 12)
                                           ).astype(np.int32)
t0 = time.time()
out = engine.generate_batch(prompts, 24)
dt = time.time() - t0
print(f"[serve] generated {out.shape} tokens in {dt:.1f}s "
      f"({out.size / dt:.1f} tok/s, CIM emulate-mode weights)")
print(f"[serve] continuations[0]: {out[0].tolist()}")

# slot engine with mixed-length requests
eng = ServingEngine(model, cfg, params, batch_size=2, max_len=64)
rids = [eng.submit([1, 2, 3], 6), eng.submit([9, 8], 4), eng.submit([5], 5)]
done = {}
while len(done) < 3:
    for fin in eng.step():
        done[fin["rid"]] = fin["tokens"]
print(f"[serve] slot engine finished {len(done)} requests: "
      f"{[len(v) for v in done.values()]} new tokens each")
