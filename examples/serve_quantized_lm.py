"""Serving example: batched generation from a CIM deploy artifact —
weights live as int8 digit planes with fused per-column dequant scales
(the memory-roofline win for decode), served through the fused Pallas
deploy path from a DeployArtifact loaded off disk.

Lifecycle exercised end to end: init (emulate QAT params) -> pack_model
-> DeployArtifact.save -> DeployArtifact.load -> engine_from_artifact,
with a logits-parity check between the emulate path and the served
deploy path.

  PYTHONPATH=src python examples/serve_quantized_lm.py
"""
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import model_artifact
from repro.configs.registry import get_config
from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity as G
from repro.models.registry import get_model
from repro.nn import init_params
from repro.serve.engine import ServingEngine, engine_from_artifact

cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                weight_granularity=G.COLUMN, psum_granularity=G.COLUMN)
cfg = get_config("qwen3-0.6b", reduced=True, cim=cim)
model = get_model(cfg)
params = init_params(model.specs(cfg), jax.random.PRNGKey(0))

# pack every CIM linear in the tree and ship it as a versioned artifact —
# the same bytes a production server would load
artifact = model_artifact(params, cim, meta={"arch": "qwen3-0.6b-reduced"})
with tempfile.TemporaryDirectory() as d:
    artifact.save(d)
    loaded_path_artifact = type(artifact).load(d)
print(f"[serve] packed model artifact: layout_version="
      f"{loaded_path_artifact.layout_version}, backend="
      f"{loaded_path_artifact.config.mode!r}")

B = 4
prompts = np.random.RandomState(0).randint(0, cfg.vocab, (B, 12)
                                           ).astype(np.int32)

# parity: emulate logits vs deploy logits from the LOADED artifact
deploy_cfg = dataclasses.replace(cfg, cim=loaded_path_artifact.config)
cache_e = model.init_cache(cfg, B, 128)
cache_d = model.init_cache(deploy_cfg, B, 128)
logits_e, _ = model.decode_step(params, cache_e, jnp.asarray(prompts), cfg)
logits_d, _ = model.decode_step(loaded_path_artifact.params, cache_d,
                                jnp.asarray(prompts), deploy_cfg)
diff = float(jnp.max(jnp.abs(logits_e.astype(jnp.float32)
                             - logits_d.astype(jnp.float32))))
scale = float(jnp.max(jnp.abs(logits_e.astype(jnp.float32)))) + 1e-9
assert diff / scale < 5e-2, (
    f"deploy logits diverge from emulate: max|diff|={diff:.3e} "
    f"(rel {diff / scale:.3e})")
print(f"[serve] emulate vs deploy logits max |diff|: {diff:.2e} "
      f"(rel {diff / scale:.2e}) — within tolerance")

# serve from the loaded artifact on the deploy backend
engine = engine_from_artifact(loaded_path_artifact, cfg, batch_size=B,
                              max_len=128)
t0 = time.time()
out = engine.generate_batch(prompts, 24)
dt = time.time() - t0
print(f"[serve] generated {out.shape} tokens in {dt:.1f}s "
      f"({out.size / dt:.1f} tok/s, int digit planes on the deploy path)")
print(f"[serve] continuations[0]: {out[0].tolist()}")

# slot engine with mixed-length requests, same loaded artifact
eng = engine_from_artifact(loaded_path_artifact, cfg, batch_size=2,
                           max_len=64)
rids = [eng.submit([1, 2, 3], 6), eng.submit([9, 8], 4), eng.submit([5], 5)]
done = {}
while len(done) < 3:
    for fin in eng.step():
        done[fin["rid"]] = fin["tokens"]
print(f"[serve] slot engine finished {len(done)} requests: "
      f"{[len(v) for v in done.values()]} new tokens each")

# self-healing serving (DESIGN.md §11): the engine models a drifting chip
# (one drift realization per decode step, clocked by request count),
# watches its own logit statistics, and re-fits the per-column scales in
# service — digit planes untouched, no repack.
from repro.core.variation import DriftSchedule  # noqa: E402
from repro.serve import DriftMonitor, HealthConfig  # noqa: E402

schedule = DriftSchedule(cell_rate=2e-3, col_rate=1.5e-2)
heal = engine_from_artifact(
    loaded_path_artifact, cfg, batch_size=B, max_len=128,
    drift_key=jax.random.PRNGKey(7), drift_schedule=schedule,
    health=DriftMonitor(HealthConfig(warmup=6)))
_ = heal.generate_batch(prompts, 12)       # clean-ish: calibrates baseline
heal.t = 400                               # fast-forward the drift clock
_ = heal.generate_batch(prompts, 12)       # drifted serving, monitored
snap = heal.health()
print(f"[serve] drift score {snap['score']:.2f} at t={snap['t']} "
      f"(drifted={snap['drifted']}, fallback={snap['fallback_active']})")
delta = heal.recalibrate(probes=16)
print(f"[serve] recalibrated: ScaleDelta v{delta.delta_version} over "
      f"{len(delta.gains)} CIM nodes, health score reset to "
      f"{heal.health()['score']:.2f}")
