"""End-to-end LM training driver with the paper's technique as a
first-class feature: train a ~100M-class LM (reduced qwen3 family) with
CIM column-wise quantized projections on the synthetic token stream,
with checkpointing + auto-resume.

Full-size invocation (what you'd run on a pod):
  python -m repro.launch.train --arch qwen3-0.6b --steps 500 \
      --batch 64 --seq 1024 --cim emulate

This example runs the reduced config for a CPU-friendly demo:
  PYTHONPATH=src python examples/train_lm_cim.py [--steps 120]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full-size", action="store_true",
                    help="train the real 0.6B config (slow on CPU)")
    args = ap.parse_args()
    argv = [
        "--arch", "qwen3-0.6b",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "96",
        "--cim", "emulate", "--cim-bits", "4", "--cim-cell-bits", "2",
        "--cim-psum-bits", "6",
        "--ckpt-dir", "/tmp/repro_lm_cim_ckpt",
        "--ckpt-every", "40",
    ]
    if not args.full_size:
        argv.append("--reduced")
    raise SystemExit(train_main(argv))


if __name__ == "__main__":
    main()
