"""Paper reproduction driver: one-stage QAT of ResNet-20 with column-wise
weight + partial-sum quantization (paper Table II CIFAR-10 settings,
scaled to CPU: synthetic class-conditional images, fewer steps).

  PYTHONPATH=src python examples/train_resnet_cifar_qat.py [--steps 150]
"""
import argparse
import sys

sys.path.insert(0, "benchmarks")

from benchmarks.common import _data, evaluate, make_cim, train_qat
from repro.core.granularity import Granularity as G


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--granularity", default="column",
                    choices=["layer", "array", "column"])
    args = ap.parse_args()
    g = G(args.granularity)
    data = _data()
    print(f"[qat] one-stage QAT, weight/psum granularity = {g.value}")
    r = train_qat(make_cim(g, g), steps=args.steps, data=data)
    print(f"[qat] final loss {r['losses'][-1]:.3f}  "
          f"test acc {r['acc']*100:.2f}%  ({r['train_time']:.0f}s)")
    ceiling = train_qat(make_cim(g, g, psum_quant=False), steps=args.steps,
                        data=data)
    print(f"[qat] no-PSQ ceiling acc {ceiling['acc']*100:.2f}% "
          f"(paper's dashed line)")


if __name__ == "__main__":
    main()
