"""Serving a non-transformer zoo entry: Whisper-style speech-to-text
from a CIM deploy artifact, audio in through the conv deploy kernel.

The encoder's two-conv stem runs on raw log-mel frames through the fused
``cim_conv_pallas`` path (stretched-kernel tiling, §III-C); every
attention/MLP linear serves from int8 digit planes with fused per-column
dequant. The decoder generates through ``ServingEngine`` slots with the
encoder states injected into the cross-attention cache.

Parity check: the deploy engine's generated tokens are compared against
an identically-driven emulate engine — whisper is in the zoo matrix's
bit-exact set, so greedy tokens must match exactly.

  PYTHONPATH=src python examples/serve_whisper_cim.py
"""
import dataclasses
import tempfile
import time

import jax
import numpy as np

from repro.api import model_artifact
from repro.configs.registry import get_config
from repro.core.cim_linear import CIMConfig
from repro.core.granularity import Granularity as G
from repro.models import whisper
from repro.models.registry import frontend_input_shape, get_model
from repro.nn import init_params
from repro.serve.engine import ServingEngine, engine_from_artifact

B, PROMPT_LEN, NEW_TOKENS = 2, 4, 12

cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4, cell_bits=2,
                act_bits=8, psum_bits=6, array_rows=32, array_cols=32,
                weight_granularity=G.COLUMN, psum_granularity=G.COLUMN)
# reduced() turns the real conv frontend on: raw mel in, not stub embeds
cfg = get_config("whisper-small", reduced=True, cim=cim).replace(
    compute_dtype="float32", remat=False)
model = get_model(cfg)
params = init_params(model.specs(cfg), jax.random.PRNGKey(0))

# synthetic "audio": raw log-mel frames at the conv stem's input shape
mel = jax.random.normal(jax.random.PRNGKey(2),
                        frontend_input_shape(cfg, B)) * 0.1
prompts = np.random.RandomState(0).randint(
    0, cfg.vocab, (B, PROMPT_LEN)).astype(np.int32)

artifact = model_artifact(params, cim, meta={"arch": "whisper-small-reduced"})
with tempfile.TemporaryDirectory() as d:
    artifact.save(d)
    loaded = type(artifact).load(d)
convs = [k for k in loaded.meta["col_shard"] if k.startswith("frontend/")]
print(f"[whisper] packed artifact: layout_version={loaded.layout_version}, "
      f"{len(loaded.meta['col_shard'])} CIM nodes "
      f"(conv planes: {convs})")


def run_engine(engine, enc_out):
    """Drive B equal-length requests through the slot engine with the
    encoder states injected into the cross-attention cache. The engine
    prefers text prompts; audio enters via ``cache['enc_out']`` — the
    decode steps cross-attend to it (generate_batch would re-init the
    cache, so we drive submit/step directly)."""
    engine.cache["enc_out"] = enc_out
    for b in range(B):
        engine.submit(prompts[b], NEW_TOKENS)
    done = {}
    while len(done) < B:
        for fin in engine.step():
            done[fin["rid"]] = fin["tokens"]
    return [done[r] for r in sorted(done)]


# emulate reference: raw params, emulate encoder feeds the engine
em_engine = ServingEngine(model, cfg, params, batch_size=B, max_len=64)
em_tokens = run_engine(em_engine, whisper.encode(params, mel, cfg))

# deploy: packed planes off disk; the conv stem runs the fused deploy
# kernel inside encode, the decoder linears serve from digit planes
dep_engine = engine_from_artifact(loaded, cfg, batch_size=B, max_len=64)
dep_cfg = dataclasses.replace(cfg, cim=loaded.config)
t0 = time.time()
enc_out = whisper.encode(loaded.params, mel, dep_cfg)
dep_tokens = run_engine(dep_engine, enc_out)
dt = time.time() - t0

n_tok = sum(len(t) for t in dep_tokens)
print(f"[whisper] deploy engine: {n_tok} tokens in {dt:.1f}s "
      f"({n_tok / dt:.1f} tok/s through conv + linear deploy kernels)")
assert em_tokens == dep_tokens, (
    f"deploy tokens diverge from emulate:\n  emulate {em_tokens}\n"
    f"  deploy  {dep_tokens}")
print(f"[whisper] generated tokens match emulate exactly: {dep_tokens[0]}")
