from .pipeline import (lm_batch_specs, make_image_dataset, make_lm_pipeline,
                       synth_classification_batch)

__all__ = ["lm_batch_specs", "make_image_dataset", "make_lm_pipeline",
           "synth_classification_batch"]
