"""Deterministic synthetic data pipelines.

Offline box: no CIFAR/ImageNet/corpora. Pipelines are (a) deterministic in
(seed, step) so restarts resume mid-epoch without data skew — the property
a production loader must have for fault tolerance — and (b) *learnable*
(structured, not iid noise) so QAT/accuracy benchmarks produce meaningful
orderings.

LM stream: a mixture of k-gram Markov chains per "document" with repeats —
cross-entropy drops well below uniform when the model learns.
Image set: class-conditional Gabor-like templates + noise; linear probes
get ~chance, convnets separate them — enough signal to rank quantization
schemes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

def _markov_tokens(key, batch, seq_len, vocab, order_states: int = 64):
    """Sample from a random sparse transition table; highly predictable."""
    k1, k2, k3 = jax.random.split(key, 3)
    # per-state candidate next tokens drawn from a concentrated sub-vocab:
    # the unigram structure alone gives a fast, reliable loss drop (from
    # log(vocab) toward log(active)), and the chain adds bigram signal
    table = jax.random.randint(k1, (order_states, 4), 0, min(64, vocab))
    start = jax.random.randint(k2, (batch,), 0, order_states)

    def step(state, k):
        choice = jax.random.randint(k, (batch,), 0, 4)
        tok = table[state % order_states, choice]
        return (state * 31 + tok) % order_states, tok

    keys = jax.random.split(k3, seq_len)
    _, toks = jax.lax.scan(step, start, keys)
    return toks.T                                            # (batch, seq)


def make_lm_pipeline(*, vocab: int, seq_len: int, global_batch: int,
                     seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": (B, T+1) int32} — model input is [:, :-1], labels
    [:, 1:]. Deterministic in (seed, step)."""
    step = 0
    fn = jax.jit(_markov_tokens, static_argnums=(1, 2, 3))
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        toks = fn(key, global_batch, seq_len + 1, vocab)
        yield {"tokens": np.asarray(toks, np.int32)}
        step += 1


def lm_batch_specs(seq_len: int, global_batch: int):
    return {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len + 1),
                                           jnp.int32)}


# ---------------------------------------------------------------------------
# synthetic image classification (paper's CIFAR stand-in)
# ---------------------------------------------------------------------------

def make_image_dataset(n_classes: int = 10, hw: int = 32, n: int = 2048,
                       seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional structured images: each class is a fixed random
    low-frequency template; samples = template + small noise + random
    shift. Returns (x (N,H,W,3) float32 in [-1,1], y (N,) int32)."""
    rng = np.random.RandomState(seed)
    # low-frequency templates via random 8x8 upsampled to hw
    base = rng.randn(n_classes, 8, 8, 3).astype(np.float32)
    templates = np.stack([
        np.stack([np.kron(base[c, :, :, ch], np.ones((hw // 8, hw // 8)))
                  for ch in range(3)], axis=-1)
        for c in range(n_classes)])
    templates /= np.abs(templates).max(axis=(1, 2, 3), keepdims=True) + 1e-6
    y = rng.randint(0, n_classes, size=n).astype(np.int32)
    x = templates[y]
    # random circular shifts + noise
    sh = rng.randint(-4, 5, size=(n, 2))
    for i in range(n):
        x[i] = np.roll(x[i], sh[i], axis=(0, 1))
    x = x + 0.25 * rng.randn(*x.shape).astype(np.float32)
    return np.clip(x, -2, 2), y


def synth_classification_batch(x, y, batch: int, step: int, seed: int = 0):
    rng = np.random.RandomState(seed * 100003 + step)
    idx = rng.randint(0, x.shape[0], size=batch)
    return x[idx], y[idx]
