"""Fused Pallas deploy path for the CIM convolution (DESIGN.md §3).

The paper's stretched-kernel tiling (§III-C, Fig. 5) makes each CIM
array's MAC a convolution over a ``c_per_array`` channel slice with all
``kh*kw`` taps resident in the array. The ``emulate`` backend
(``repro.api.backends`` registry — conv dispatch goes through
``get_backend(cfg.mode).conv``, not mode strings) realizes this as
one XLA grouped convolution, which costs two HBM round-trips the hardware
never pays: the activation channel-slices are *tiled* ``n_split``x into
the group axis, and the full (B, H', W', S, kt, C_out) partial-sum tensor
is materialized before ADC quantization.

The ``deploy`` backend's kernel here removes both:

(Cell variation rides the same lowering: ``variation_key``/
``variation_std`` pass through to the matmul kernel, which perturbs the
flattened digit planes (S, kt, kh*kw*cpa, C_out) — row-major identical to
the packed 6-D conv layout, so conv deploy and conv emulate draw the same
per-cell noise from a shared key; DESIGN.md §8.)

  1. ``ref.extract_conv_patches`` gathers each output position's
     receptive field ONCE per channel slice — (B, H', W', k_tiles, rows)
     with rows = kh*kw*c_per_array, row order (dh, dw, c) matching
     ``repro.api.pack_conv``'s digit layout. No n_split replication: the
     kernel re-reads the same patch block per bit-split via its BlockSpec
     index map (the a-operand map ignores the split index).
  2. The spatial axis flattens to M = B*H'*W' and lowers onto the fused
     CIM matmul kernel, whose grid (M/bm, C_out/bn, k_tiles, n_split)
     applies ADC quantization to each array-tile accumulator in VMEM —
     the partial-sum tensor never touches HBM (DESIGN.md §7).

VMEM working set per grid step is the linear kernel's (DESIGN.md §6);
rows = kh*kw*c_per_array <= array_rows, so conv blocks are never larger
than the linear blocks the budget was sized for.

Shard-axis invariant (DESIGN.md §10): the trailing C_out axis of the
flattened planes/scales is the column-parallel shard axis. Patches are
output-channel-independent, so the sharded serving path extracts them
once (replicated) and runs this same lowering one C_out shard per device
— keep any future patch/geometry change free of cross-output-channel
coupling or the shard_map dispatch in ``kernels/ops`` breaks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .cim_matmul import cim_matmul_pallas
from .ref import extract_conv_patches


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "c_per_array",
                     "psum_bits", "psum_quant", "block_m", "block_n",
                     "interpret"),
)
def cim_conv_pallas(
    a_int: jnp.ndarray,    # (B, H, W, C_in) integer-valued codes
    digits: jnp.ndarray,   # (S, k_tiles, kh*kw*cpa, C_out); uint8 = nibble
    s_p: jnp.ndarray,      # (S, k_tiles, C_out)
    deq: jnp.ndarray,      # (S, k_tiles, C_out)
    variation_key=None,    # optional PRNG key: one MC device realization
    variation_std=None,    # log-normal sigma (float or traced scalar)
    occ=None,              # optional (S, k_tiles, C_out) occupancy map
    *,
    kh: int,
    kw: int,
    stride: int,
    padding: str,
    c_per_array: int,
    psum_bits: int,
    psum_quant: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused CIM conv: stretched-kernel patches -> tiled matmul kernel.

    Returns (B, H', W', C_out) float32.
    """
    n_split, k_tiles, rows_d, n = digits.shape
    rows = kh * kw * c_per_array           # logical rows, from the geometry
    nibble = digits.dtype == jnp.uint8
    assert rows_d == (rows // 2 if nibble else rows), \
        (digits.shape, kh, kw, c_per_array, nibble)
    a_t = extract_conv_patches(a_int, kh, kw, stride, padding, k_tiles,
                               c_per_array)
    b, ho, wo = a_t.shape[:3]
    out = cim_matmul_pallas(
        a_t.reshape(b * ho * wo, k_tiles, rows),
        digits, s_p, deq, variation_key, variation_std, occ,
        psum_bits=psum_bits, psum_quant=psum_quant,
        # each of the kh*kw taps is its own packed nibble block in the
        # flattened row layout (repro.core.nibble)
        nibble_groups=kh * kw,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return out.reshape(b, ho, wo, n)
