# Pallas TPU kernels for the paper's compute hot-spots: the fused
# bit-split x array-tiled CIM matmul and the stretched-kernel CIM conv,
# both with in-VMEM partial-sum (ADC) quantization. ops.py = jitted
# wrappers, ref.py = pure-jnp oracles.
from . import ops, ref
from .cim_conv import cim_conv_pallas
from .cim_matmul import cim_matmul_pallas

__all__ = ["ops", "ref", "cim_conv_pallas", "cim_matmul_pallas"]
