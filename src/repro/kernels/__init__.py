# Pallas TPU kernels for the paper's compute hot-spot: the fused
# bit-split x array-tiled CIM matmul with in-VMEM partial-sum (ADC)
# quantization. ops.py = jitted wrappers, ref.py = pure-jnp oracles.
from . import ops, ref
from .cim_matmul import cim_matmul_pallas

__all__ = ["ops", "ref", "cim_matmul_pallas"]
