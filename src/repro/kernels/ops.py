"""Jitted public wrappers around the Pallas kernels.

``use_kernel=True`` runs the Pallas kernel (interpret mode off-TPU so the
kernel body is validated on CPU); ``use_kernel=False`` runs the pure-jnp
oracle — used for allocation-free dry-runs where the HLO must be portable.

Both wrappers accept ``variation_key``/``variation_std``: when set, the
digit planes are evaluated under one Monte-Carlo realization of log-normal
cell noise (paper §IV-E). The kernel path draws the noise inside
``cim_matmul_pallas`` (before block padding); the oracle path perturbs
here with the same ``repro.core.variation.perturb_digits``, so kernel and
oracle stay bit-comparable under a shared key (DESIGN.md §8).

Both wrappers also accept ``mesh``/``mesh_axis``: when a mesh with more
than one device along ``mesh_axis`` (default ``"model"``) is given, the
packed digit planes and their column scales are sharded column-wise over
that axis via ``shard_map`` — each device runs the kernel on its own
output-column shard (per-column ADC + dequant scales are local by
construction, DESIGN.md §10), and the only cross-device collective is one
all-gather of the final dequantized activations. Ragged column counts pad
the last shard (scale 1, deq 0 — dead columns) and slice after the
gather, mirroring the kernel's own last-block padding. Cell-variation
noise is always drawn on the FULL unpadded packed planes *before*
sharding, so a sharded evaluation is bit-exact with the single-device
evaluation under the same key.

Observability (DESIGN.md §12): when the ``repro.obs.adc`` collector is
armed, both wrappers emit a per-column ADC saturation side-output — the
partial sums are recomputed by a jnp einsum next to the kernel call
(the fused kernel itself never materializes them; that is the point of
fusion) and reduced to per-column clipped-conversion counts. The main
output is untouched, bit-exact with the un-instrumented path, and the
disarmed path contains no side computation at all. Arming is a
trace-time decision — see ``repro.obs.adc``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.nibble import unpack_nibbles
from repro.core.variation import perturb_digits, variation_wanted
from repro.obs import adc as obs_adc

from . import ref
from .cim_adc_free import cim_conv_adc_free_pallas, cim_matmul_adc_free_pallas
from .cim_conv import cim_conv_pallas
from .cim_matmul import cim_matmul_experts_pallas, cim_matmul_pallas

#: Mesh axis the packed column (output-channel) dimension shards over by
#: default — the tensor-parallel axis of the serving meshes (launch/serve
#: --mesh, DESIGN.md §10).
COL_SHARD_AXIS = "model"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def col_shards(mesh, mesh_axis: str = COL_SHARD_AXIS) -> int:
    """Number of column shards a mesh implies (1 = unsharded dispatch)."""
    if mesh is None or mesh_axis not in getattr(mesh, "axis_names", ()):
        return 1
    return int(mesh.shape[mesh_axis])


def pad_cols(digits, s_p, deq, n_shards: int, occ=None):
    """Pad the packed column axis to a multiple of ``n_shards``.

    Dead columns get digit 0, psum scale 1, dequant scale 0 and occupancy
    0 — exactly the kernel's last-block padding rule — so they contribute
    nothing (the sparse kernels skip them outright) and are sliced off
    after the output gather. Digit planes pad the same way whether dense
    or nibble-packed: the column axis is never the packed axis, so shard
    boundaries stay byte-aligned."""
    n = digits.shape[-1]
    pad = (-n) % n_shards
    if pad:
        digits = jnp.pad(digits, [(0, 0)] * (digits.ndim - 1) + [(0, pad)])
        s_p = jnp.pad(s_p, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
        deq = jnp.pad(deq, ((0, 0), (0, 0), (0, pad)))
        if occ is not None:
            occ = jnp.pad(occ, ((0, 0), (0, 0), (0, pad)))
    return digits, s_p, deq, occ


def _record_saturation(a2, digits, s_p, *, psum_bits, variation_key,
                       variation_std, nibble_groups: int = 1):
    """ADC saturation side-output for the fused paths (armed only).

    The deploy kernel never materializes partial sums, so the armed
    trace recomputes them with the reference einsum — including the
    cell-noise realization, so the counts describe the digits the
    kernel actually multiplied — and ships per-column clipped counts
    host-side. Nothing here feeds the main output."""
    d = digits
    if d.dtype == jnp.uint8:
        d = unpack_nibbles(d, groups=nibble_groups)
    elif d.dtype == jnp.int4:
        d = d.astype(jnp.int8)
    if variation_wanted(variation_key, variation_std):
        d = perturb_digits(d, variation_key, variation_std)
    psum = jnp.einsum("mtr,strn->mstn", a2.astype(jnp.float32),
                      d.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    obs_adc.record(psum, s_p, psum_bits)


def _cim_matmul_sharded(
    a2, digits, s_p, deq, mesh, mesh_axis, *,
    psum_bits, psum_quant, use_kernel, block_m, block_n,
    variation_key, variation_std, adc_free=False, occ=None,
    nibble_groups=1,
):
    """Column-parallel CIM matmul: one kernel shard per device.

    a2 (M, k_tiles, rows) is replicated; digits/s_p/deq (and the optional
    occupancy map) shard over their last (column) axis. Nibble-packed
    uint8 planes stream through shard_map at their packed byte width —
    the column axis is never the packed axis, so shard boundaries are
    byte-aligned by construction. No partial sum crosses a device
    boundary — the reduction dims (array tile, bit-split) live inside
    each shard's grid — so the single collective is the all-gather of
    (M, N/D) f32 outputs.
    """
    from jax.sharding import PartitionSpec as P

    from repro.nn.module import shard_map  # lazy: avoids import cycle

    if digits.dtype == jnp.int4:
        # dense int4 is a legacy HBM storage dtype; the kernel loads int8
        digits = digits.astype(jnp.int8)
    if variation_wanted(variation_key, variation_std):
        # full unpadded packed LOGICAL layout, BEFORE shard padding: same
        # noise indices as the single-device paths (DESIGN.md §8, §10)
        if digits.dtype == jnp.uint8:
            digits = unpack_nibbles(digits, groups=nibble_groups)
        digits = perturb_digits(digits, variation_key, variation_std)
    if not use_kernel and digits.dtype == jnp.uint8:
        # the jnp oracles consume logical planes only
        digits = unpack_nibbles(digits, groups=nibble_groups)
    n = digits.shape[-1]
    n_shards = mesh.shape[mesh_axis]
    digits, s_p, deq, occ = pad_cols(digits, s_p, deq, n_shards, occ)
    interp = not _on_tpu()

    def local(a_, d_, sp_, dq_, *rest):
        occ_ = rest[0] if rest else None
        if adc_free:
            # ADC-free style (DESIGN.md §13): no s_p stream — sp_ rides
            # the shard_map signature so the specs stay uniform, unused
            if use_kernel:
                out = cim_matmul_adc_free_pallas(
                    a_, d_, dq_, None, None, occ_,
                    nibble_groups=nibble_groups,
                    block_m=block_m, block_n=block_n, interpret=interp)
            else:
                out = ref.cim_matmul_adc_free_ref(a_, d_, dq_)
        elif use_kernel:
            out = cim_matmul_pallas(
                a_, d_, sp_, dq_, None, None, occ_,
                psum_bits=psum_bits, psum_quant=psum_quant,
                nibble_groups=nibble_groups,
                block_m=block_m, block_n=block_n, interpret=interp)
        else:
            out = ref.cim_matmul_ref(a_, d_, sp_, dq_, psum_bits=psum_bits,
                                     psum_quant=psum_quant)
        return jax.lax.all_gather(out, mesh_axis, axis=1, tiled=True)

    col = P(*([None] * (digits.ndim - 1) + [mesh_axis]))
    col3 = P(None, None, mesh_axis)
    args = (a2, digits, s_p, deq)
    in_specs = (P(), col, col3, col3)
    if occ is not None and use_kernel:
        args += (occ.astype(jnp.uint8),)
        in_specs += (col3,)
    out = shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=P(), check_vma=False,
    )(*args)
    return out[:, :n]


def cim_matmul(
    a_t: jnp.ndarray,
    digits: jnp.ndarray,
    s_p: jnp.ndarray,
    deq: jnp.ndarray,
    *,
    psum_bits: int,
    psum_quant: bool = True,
    use_kernel: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    variation_key=None,
    variation_std=None,
    mesh=None,
    mesh_axis: str = COL_SHARD_AXIS,
    adc_free: bool = False,
    occ=None,
) -> jnp.ndarray:
    """CIM matmul over pre-tiled inputs.

    a_t:    (..., k_tiles, rows) integer-valued activations
    digits: (S, k_tiles, rows, N) int8 cell planes — or nibble-packed
            uint8 (S, k_tiles, rows // 2, N), DESIGN.md §14
    s_p:    (S, k_tiles, N) ADC scales
    deq:    (S, k_tiles, N) fused dequant scales (2^{cs} * s_w * s_a)
    variation_key/std: optional log-normal cell-noise realization
    mesh/mesh_axis: column-shard the planes over this mesh axis (>1
        device: shard_map column-parallel dispatch, bit-exact with the
        single-device path; DESIGN.md §10)
    adc_free: dispatch the ADC-free hardware style (DESIGN.md §13) —
        exact digital psum accumulation, s_p ignored, no saturation
        side-output (there is no ADC to saturate)
    occ: optional (S, k_tiles, N) uint8 occupancy map — the kernels skip
        unoccupied digit planes, bit-exact with the dense evaluation
        (DESIGN.md §14); ignored by the jnp oracle paths
    returns (..., N) float32
    """
    batch_shape = a_t.shape[:-2]
    m = 1
    for d in batch_shape:
        m *= d
    a2 = a_t.reshape((m,) + a_t.shape[-2:])
    if obs_adc.enabled() and psum_quant and not adc_free:
        _record_saturation(a2, digits, s_p, psum_bits=psum_bits,
                           variation_key=variation_key,
                           variation_std=variation_std)
    if col_shards(mesh, mesh_axis) > 1:
        out = _cim_matmul_sharded(
            a2, digits, s_p, deq, mesh, mesh_axis,
            psum_bits=psum_bits, psum_quant=psum_quant,
            use_kernel=use_kernel, block_m=block_m, block_n=block_n,
            variation_key=variation_key, variation_std=variation_std,
            adc_free=adc_free, occ=occ)
    elif adc_free and use_kernel:
        out = cim_matmul_adc_free_pallas(
            a2, digits, deq, variation_key, variation_std, occ,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    elif adc_free:
        if digits.dtype == jnp.uint8:
            digits = unpack_nibbles(digits)
        if variation_wanted(variation_key, variation_std):
            digits = perturb_digits(digits, variation_key, variation_std)
        out = ref.cim_matmul_adc_free_ref(a2, digits, deq)
    elif use_kernel:
        out = cim_matmul_pallas(
            a2, digits, s_p, deq, variation_key, variation_std, occ,
            psum_bits=psum_bits, psum_quant=psum_quant,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    else:
        if digits.dtype == jnp.uint8:
            digits = unpack_nibbles(digits)
        if variation_wanted(variation_key, variation_std):
            digits = perturb_digits(digits, variation_key, variation_std)
        out = ref.cim_matmul_ref(
            a2, digits, s_p, deq,
            psum_bits=psum_bits, psum_quant=psum_quant,
        )
    return out.reshape(batch_shape + (digits.shape[-1],))


def cim_matmul_experts(
    a_t: jnp.ndarray,      # (E, C, k_tiles, rows) integer-valued
    digits: jnp.ndarray,   # (E, S, k_tiles, rows, N) cell planes
    s_p: jnp.ndarray,      # (E, S, k_tiles, N)
    deq: jnp.ndarray,      # (E, S, k_tiles, N)
    *,
    psum_bits: int,
    psum_quant: bool = True,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """Batched MoE expert-bank dispatch: every expert's capacity buffer
    through ONE kernel launch (expert index = leading grid dimension),
    bit-exact with ``lax.map`` of ``cim_matmul`` over experts — same
    block shapes, same (t, s) accumulation order per output block.

    The caller (``models.layers._expert_matmul``) gates this to the
    plain deploy fast path: single-device (no column-sharded mesh),
    ``use_kernel``, no per-call variation, saturation collector unarmed,
    bank small enough to stream. Everything outside that gate falls back
    to ``lax.map``. Returns (E, C, N) float32."""
    if digits.dtype == jnp.uint8:
        # nibble-packed expert bank: unpack host-side — the batched
        # experts kernel streams logical int8 planes (the nibble win is
        # the artifact/HBM-resident layout; the bank gate already bounds
        # the bank to ≤4 MiB so the upcast stays cheap)
        digits = unpack_nibbles(digits)
    elif digits.dtype == jnp.int4:
        digits = digits.astype(jnp.int8)
    return cim_matmul_experts_pallas(
        a_t, digits, s_p, deq,
        psum_bits=psum_bits, psum_quant=psum_quant,
        block_m=block_m, block_n=block_n,
        interpret=not _on_tpu(),
    )


def cim_conv(
    a_int: jnp.ndarray,
    digits: jnp.ndarray,
    s_p: jnp.ndarray,
    deq: jnp.ndarray,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding="SAME",
    c_per_array: int,
    psum_bits: int,
    psum_quant: bool = True,
    use_kernel: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    variation_key=None,
    variation_std=None,
    mesh=None,
    mesh_axis: str = COL_SHARD_AXIS,
    adc_free: bool = False,
    occ=None,
) -> jnp.ndarray:
    """CIM conv over activation codes and packed conv digit planes.

    a_int:  (B, H, W, C_in) integer-valued activation codes
    digits: (S, k_tiles, kh*kw*c_per_array, C_out) cell planes in the
            stretched-kernel row layout (see repro.api.pack_conv) — or
            nibble-packed uint8 (S, k_tiles, kh*kw*(c_per_array // 2),
            C_out), each tap its own packed block (DESIGN.md §14)
    s_p:    (S, k_tiles, C_out) ADC scales
    deq:    (S, k_tiles, C_out) fused dequant scales
    variation_key/std: optional log-normal cell-noise realization
    mesh/mesh_axis: column-shard the planes over this mesh axis — the
        C_out axis for conv (DESIGN.md §10); bit-exact with single-device
    occ: optional (S, k_tiles, C_out) uint8 occupancy map (DESIGN.md §14)
    returns (B, H', W', C_out) float32
    """
    if digits.dtype == jnp.int4:
        # dense int4 is a legacy HBM storage dtype; the kernel loads int8
        digits = digits.astype(jnp.int8)
    if not isinstance(padding, str):
        # hashable for the jit static arg
        padding = tuple((int(lo), int(hi)) for lo, hi in padding)
    if obs_adc.enabled() and psum_quant and not adc_free:
        k_tiles = digits.shape[1]
        p_t = ref.extract_conv_patches(a_int, kh, kw, stride, padding,
                                       k_tiles, c_per_array)
        b_, ho_, wo_ = p_t.shape[:3]
        _record_saturation(
            p_t.reshape(b_ * ho_ * wo_, k_tiles, p_t.shape[-1]),
            digits, s_p, psum_bits=psum_bits,
            variation_key=variation_key, variation_std=variation_std,
            nibble_groups=kh * kw)
    if col_shards(mesh, mesh_axis) > 1:
        # same lowering as cim_conv_pallas: patches once (replicated),
        # then the column-parallel matmul grid over the C_out shards
        k_tiles = digits.shape[1]
        rows = kh * kw * c_per_array    # logical rows, from the geometry
        a_t = ref.extract_conv_patches(a_int, kh, kw, stride, padding,
                                       k_tiles, c_per_array)
        b, ho, wo = a_t.shape[:3]
        out = _cim_matmul_sharded(
            a_t.reshape(b * ho * wo, k_tiles, rows), digits, s_p, deq,
            mesh, mesh_axis, psum_bits=psum_bits, psum_quant=psum_quant,
            use_kernel=use_kernel, block_m=block_m, block_n=block_n,
            variation_key=variation_key, variation_std=variation_std,
            adc_free=adc_free, occ=occ, nibble_groups=kh * kw)
        return out.reshape(b, ho, wo, digits.shape[-1])
    if adc_free and use_kernel:
        return cim_conv_adc_free_pallas(
            a_int, digits, deq, variation_key, variation_std, occ,
            kh=kh, kw=kw, stride=stride, padding=padding,
            c_per_array=c_per_array,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    if adc_free:
        if digits.dtype == jnp.uint8:
            digits = unpack_nibbles(digits, groups=kh * kw)
        if variation_wanted(variation_key, variation_std):
            digits = perturb_digits(digits, variation_key, variation_std)
        k_tiles, rows = digits.shape[1], digits.shape[2]
        a_t = ref.extract_conv_patches(a_int.astype(jnp.float32), kh, kw,
                                       stride, padding, k_tiles,
                                       c_per_array)
        b, ho, wo = a_t.shape[:3]
        out = ref.cim_matmul_adc_free_ref(
            a_t.reshape(b * ho * wo, k_tiles, rows), digits, deq)
        return out.reshape(b, ho, wo, digits.shape[-1])
    if use_kernel:
        return cim_conv_pallas(
            a_int, digits, s_p, deq, variation_key, variation_std, occ,
            kh=kh, kw=kw, stride=stride, padding=padding,
            c_per_array=c_per_array,
            psum_bits=psum_bits, psum_quant=psum_quant,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    if digits.dtype == jnp.uint8:
        digits = unpack_nibbles(digits, groups=kh * kw)
    if variation_wanted(variation_key, variation_std):
        digits = perturb_digits(digits, variation_key, variation_std)
    return ref.cim_conv_ref(
        a_int, digits, s_p, deq,
        kh=kh, kw=kw, stride=stride, padding=padding,
        c_per_array=c_per_array,
        psum_bits=psum_bits, psum_quant=psum_quant,
    )
