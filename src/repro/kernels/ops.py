"""Jitted public wrappers around the Pallas kernels.

``use_kernel=True`` runs the Pallas kernel (interpret mode off-TPU so the
kernel body is validated on CPU); ``use_kernel=False`` runs the pure-jnp
oracle — used for allocation-free dry-runs where the HLO must be portable.

Both wrappers accept ``variation_key``/``variation_std``: when set, the
digit planes are evaluated under one Monte-Carlo realization of log-normal
cell noise (paper §IV-E). The kernel path draws the noise inside
``cim_matmul_pallas`` (before block padding); the oracle path perturbs
here with the same ``repro.core.variation.perturb_digits``, so kernel and
oracle stay bit-comparable under a shared key (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.variation import perturb_digits, variation_wanted

from . import ref
from .cim_conv import cim_conv_pallas
from .cim_matmul import cim_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cim_matmul(
    a_t: jnp.ndarray,
    digits: jnp.ndarray,
    s_p: jnp.ndarray,
    deq: jnp.ndarray,
    *,
    psum_bits: int,
    psum_quant: bool = True,
    use_kernel: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    variation_key=None,
    variation_std=None,
) -> jnp.ndarray:
    """CIM matmul over pre-tiled inputs.

    a_t:    (..., k_tiles, rows) integer-valued activations
    digits: (S, k_tiles, rows, N) int8 cell planes
    s_p:    (S, k_tiles, N) ADC scales
    deq:    (S, k_tiles, N) fused dequant scales (2^{cs} * s_w * s_a)
    variation_key/std: optional log-normal cell-noise realization
    returns (..., N) float32
    """
    batch_shape = a_t.shape[:-2]
    m = 1
    for d in batch_shape:
        m *= d
    a2 = a_t.reshape((m,) + a_t.shape[-2:])
    if use_kernel:
        out = cim_matmul_pallas(
            a2, digits, s_p, deq, variation_key, variation_std,
            psum_bits=psum_bits, psum_quant=psum_quant,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    else:
        if variation_wanted(variation_key, variation_std):
            digits = perturb_digits(digits, variation_key, variation_std)
        out = ref.cim_matmul_ref(
            a2, digits, s_p, deq,
            psum_bits=psum_bits, psum_quant=psum_quant,
        )
    return out.reshape(batch_shape + (digits.shape[-1],))


def cim_conv(
    a_int: jnp.ndarray,
    digits: jnp.ndarray,
    s_p: jnp.ndarray,
    deq: jnp.ndarray,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding="SAME",
    c_per_array: int,
    psum_bits: int,
    psum_quant: bool = True,
    use_kernel: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    variation_key=None,
    variation_std=None,
) -> jnp.ndarray:
    """CIM conv over activation codes and packed conv digit planes.

    a_int:  (B, H, W, C_in) integer-valued activation codes
    digits: (S, k_tiles, kh*kw*c_per_array, C_out) cell planes in the
            stretched-kernel row layout (see repro.api.pack_conv)
    s_p:    (S, k_tiles, C_out) ADC scales
    deq:    (S, k_tiles, C_out) fused dequant scales
    variation_key/std: optional log-normal cell-noise realization
    returns (B, H', W', C_out) float32
    """
    if digits.dtype == jnp.int4:
        # int4 is the HBM storage dtype; the kernel loads via int8
        digits = digits.astype(jnp.int8)
    if not isinstance(padding, str):
        # hashable for the jit static arg
        padding = tuple((int(lo), int(hi)) for lo, hi in padding)
    if use_kernel:
        return cim_conv_pallas(
            a_int, digits, s_p, deq, variation_key, variation_std,
            kh=kh, kw=kw, stride=stride, padding=padding,
            c_per_array=c_per_array,
            psum_bits=psum_bits, psum_quant=psum_quant,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    if variation_wanted(variation_key, variation_std):
        digits = perturb_digits(digits, variation_key, variation_std)
    return ref.cim_conv_ref(
        a_int, digits, s_p, deq,
        kh=kh, kw=kw, stride=stride, padding=padding,
        c_per_array=c_per_array,
        psum_bits=psum_bits, psum_quant=psum_quant,
    )
