"""Jitted public wrappers around the Pallas kernels.

``use_kernel=True`` runs the Pallas kernel (interpret mode off-TPU so the
kernel body is validated on CPU); ``use_kernel=False`` runs the pure-jnp
oracle — used for allocation-free dry-runs where the HLO must be portable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .cim_matmul import cim_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cim_matmul(
    a_t: jnp.ndarray,
    digits: jnp.ndarray,
    s_p: jnp.ndarray,
    deq: jnp.ndarray,
    *,
    psum_bits: int,
    psum_quant: bool = True,
    use_kernel: bool = True,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """CIM matmul over pre-tiled inputs.

    a_t:    (..., k_tiles, rows) integer-valued activations
    digits: (S, k_tiles, rows, N) int8 cell planes
    s_p:    (S, k_tiles, N) ADC scales
    deq:    (S, k_tiles, N) fused dequant scales (2^{cs} * s_w * s_a)
    returns (..., N) float32
    """
    batch_shape = a_t.shape[:-2]
    m = 1
    for d in batch_shape:
        m *= d
    a2 = a_t.reshape((m,) + a_t.shape[-2:])
    if use_kernel:
        out = cim_matmul_pallas(
            a2, digits, s_p, deq,
            psum_bits=psum_bits, psum_quant=psum_quant,
            block_m=block_m, block_n=block_n,
            interpret=not _on_tpu(),
        )
    else:
        out = ref.cim_matmul_ref(
            a2, digits, s_p, deq,
            psum_bits=psum_bits, psum_quant=psum_quant,
        )
    return out.reshape(batch_shape + (digits.shape[-1],))
