"""Pallas TPU kernel: ADC-free CIM matmul with digital psum accumulation.

The ``adc_free`` hardware style (HCiM-style hybrid analog-digital CIM,
PAPERS.md) removes the per-column ADC from the array pipeline: each
(split, array-tile, column) partial sum leaves the array as an exact
integer — bit-sliced MACs are accumulated *digitally* — so there is no
psum quantization step at all. The psum_bits knob stops being an ADC
resolution and becomes the digital accumulator width the cost model
charges (benchmarks/bench_hw_cost.layer_cost(style="adc_free")); the
kernel itself accumulates exactly.

Relative to ``kernels/cim_matmul._kernel`` the body drops the ADC stage
(round -> scale -> clip -> rescale in VMEM) *and* the s_p operand — the
per-column ADC scale stream never leaves HBM because it does not exist
on this hardware. Everything else is deliberately identical: same grid
(M/bm, N/bn, k_tiles, n_split) with the reduction dims iterating
fastest, same packed digit-plane layout, same trailing-N column-shard
contract (kernels/ops dispatches this kernel per column shard under
shard_map unchanged, DESIGN.md §10), and cell variation is injected on
the unpadded packed planes before the pallas_call exactly like the ADC
kernel — ``perturb_packed`` semantics carry over untouched (§8).

Bit-exactness contract: psums are integer-valued (int x int MACs), so
``jnp.round`` on the f32 accumulator is the identity up to float
roundoff snapping — the same snap the ADC kernel applies before
quantizing. Consequently ``adc_free`` output == the ADC kernel's output
whenever the ADC is transparent (s_p == 1 and psum_bits wide enough
that no column clips), which is what the hypothesis property tests in
tests/test_backends.py pin down.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nibble import unpack_nibbles
from repro.core.variation import perturb_digits, variation_wanted

from .cim_matmul import decode_digit_block
from .ref import extract_conv_patches


def _kernel(a_ref, d_ref, deq_ref, o_ref, *, nibble: bool = False,
            groups: int = 1):
    s = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when(jnp.logical_and(t == 0, s == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[:, 0, :].astype(jnp.float32)          # (bm, rows)
    d = decode_digit_block(d_ref[0, 0], nibble=nibble, groups=groups)
    p = jnp.dot(a, d, preferred_element_type=jnp.float32)  # (bm, bn)
    # digital accumulation: snap the integer-valued MACs (kills float
    # roundoff, matching the ADC kernel's pre-quantize snap) and add the
    # dequantized word straight into the accumulator — no ADC stage
    p = jnp.round(p)
    deq = deq_ref[0, 0, :].astype(jnp.float32)      # (bn,)
    o_ref[...] += p * deq[None, :]


def _kernel_sparse(a_ref, d_ref, occ_ref, deq_ref, o_ref, *,
                   nibble: bool = False, groups: int = 1):
    """Occupancy-aware ADC-free body: a (bn-column) block whose digit
    planes are ALL unoccupied skips the MAC entirely; any occupied column
    makes the block run the verbatim dense body (per-column masking
    between multiply and accumulate perturbs XLA fusion at 1 ulp). No
    compensation exists here (unlike the sign-ADC case): an all-zero
    plane's exact digital psum is 0, so the dense path adds +0.0 and the
    skip adds nothing — bit-identical on a +0.0-initialized f32
    accumulator (round-to-nearest never produces -0.0 from +0.0)."""
    s = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when(jnp.logical_and(t == 0, s == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    occ = occ_ref[0, 0, :]                          # (bn,) uint8

    @pl.when(jnp.any(occ > 0))
    def _mac():
        a = a_ref[:, 0, :].astype(jnp.float32)
        d = decode_digit_block(d_ref[0, 0], nibble=nibble, groups=groups)
        p = jnp.round(jnp.dot(a, d, preferred_element_type=jnp.float32))
        deq = deq_ref[0, 0, :].astype(jnp.float32)
        o_ref[...] += p * deq[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("nibble_groups", "block_m", "block_n", "interpret"),
)
def cim_matmul_adc_free_pallas(
    a_t: jnp.ndarray,      # (M, k_tiles, rows) integer-valued
    digits: jnp.ndarray,   # (S, k_tiles, rows, N); uint8 = nibble-packed
    deq: jnp.ndarray,      # (S, k_tiles, N) fused dequant scales
    variation_key=None,    # optional PRNG key: one MC device realization
    variation_std=None,    # log-normal sigma (float or traced scalar)
    occ=None,              # optional (S, k_tiles, N) uint8 occupancy map
    *,
    nibble_groups: int = 1,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """ADC-free CIM matmul: digital accumulation of bit-sliced psums.

    Same operands as ``cim_matmul_pallas`` minus ``s_p`` (no ADC scale
    stream exists on this hardware style). Returns (M, N) float32.
    """
    nibble = digits.dtype == jnp.uint8   # nibble-packed HBM planes (§14)
    if variation_wanted(variation_key, variation_std):
        # perturb BEFORE block padding: noise indices must match the
        # packed (unpadded) LOGICAL layout the emulate path perturbs (§8)
        if nibble:
            digits = unpack_nibbles(digits, groups=nibble_groups)
            nibble = False
        digits = perturb_digits(digits, variation_key, variation_std)
    m, k_tiles, rows = a_t.shape
    n_split = digits.shape[0]
    n = digits.shape[-1]
    rows_d = digits.shape[2]             # stored rows: rows/2 when nibble
    assert rows_d == (rows // 2 if nibble else rows), \
        (digits.shape, a_t.shape, nibble)

    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        a_t = jnp.pad(a_t, ((0, pad_m), (0, 0), (0, 0)))
    if pad_n:
        digits = jnp.pad(digits, ((0, 0), (0, 0), (0, 0), (0, pad_n)))
        deq = jnp.pad(deq, ((0, 0), (0, 0), (0, pad_n)))
        if occ is not None:
            occ = jnp.pad(occ, ((0, 0), (0, 0), (0, pad_n)))  # dead: skip
    mp, np_ = m + pad_m, n + pad_n

    # reduction dims (s outer, t inner): the digital accumulator adds the
    # dequantized words in the SAME row-major (s, t) order the oracle's
    # einsum reduction uses — unquantized psums carry full mantissas, so
    # (unlike the ADC kernel's coarse post-quantization words) any
    # reassociation here is visible at 1 ulp and amplifies through the
    # next layer's activation-code rounding at model scale
    grid = (mp // bm, np_ // bn, n_split, k_tiles)
    col_spec = pl.BlockSpec((1, 1, bn), lambda i, j, s, t: (s, t, j))
    in_specs = [
        pl.BlockSpec((bm, 1, rows), lambda i, j, s, t: (i, t, 0)),
        pl.BlockSpec((1, 1, rows_d, bn), lambda i, j, s, t: (s, t, 0, j)),
    ]
    if occ is None:
        body = _kernel
        args = (a_t, digits, deq)
    else:
        body = _kernel_sparse
        args = (a_t, digits, occ.astype(jnp.uint8), deq)
        in_specs.append(col_spec)
    in_specs.append(col_spec)
    out = pl.pallas_call(
        functools.partial(body, nibble=nibble, groups=nibble_groups),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "c_per_array",
                     "block_m", "block_n", "interpret"),
)
def cim_conv_adc_free_pallas(
    a_int: jnp.ndarray,    # (B, H, W, C_in) integer-valued codes
    digits: jnp.ndarray,   # (S, k_tiles, kh*kw*cpa, C_out); uint8 = nibble
    deq: jnp.ndarray,      # (S, k_tiles, C_out)
    variation_key=None,
    variation_std=None,
    occ=None,              # optional (S, k_tiles, C_out) occupancy map
    *,
    kh: int,
    kw: int,
    stride: int,
    padding: str,
    c_per_array: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """ADC-free CIM conv: same stretched-kernel lowering as
    ``kernels.cim_conv.cim_conv_pallas`` (patches once, flatten spatial
    to M, run the tiled matmul grid) but onto the ADC-free kernel.

    Returns (B, H', W', C_out) float32.
    """
    n_split, k_tiles, rows_d, n = digits.shape
    rows = kh * kw * c_per_array           # logical rows, from the geometry
    nibble = digits.dtype == jnp.uint8
    assert rows_d == (rows // 2 if nibble else rows), \
        (digits.shape, kh, kw, c_per_array, nibble)
    a_t = extract_conv_patches(a_int, kh, kw, stride, padding, k_tiles,
                               c_per_array)
    b, ho, wo = a_t.shape[:3]
    out = cim_matmul_adc_free_pallas(
        a_t.reshape(b * ho * wo, k_tiles, rows),
        digits, deq, variation_key, variation_std, occ,
        # each of the kh*kw taps is its own packed nibble block in the
        # flattened row layout (repro.core.nibble)
        nibble_groups=kh * kw,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return out.reshape(b, ho, wo, n)
