"""Pallas TPU kernel: fused CIM matmul with partial-sum (ADC) quantization.

TPU-native realization of the paper's array pipeline (DESIGN.md §2).
This is the arithmetic behind the ``deploy`` backend of the
``repro.api.backends`` registry (``CIMConfig.mode`` is a backend name;
dispatch happens through ``get_backend``, not mode strings): the CIM
array boundary becomes the K-grid dimension of a tiled matmul, and the
ADC's per-column quantization is applied to each array-tile's accumulator
*in VMEM* before cross-array shift-and-add — the (M, S, kt, N) partial-sum
tensor never exists in HBM on this path (the ``emulate`` backend still
materializes it, deliberately, so LSQ gradients can flow through the ADC).

Grid: (M/bm, N/bn, k_tiles, n_split); the two reduction dims (array tile
t, bit-split s) iterate fastest so output-block revisits are consecutive
and the accumulation stays resident. The conv deploy path
(kernels/cim_conv) lowers onto this same grid with M = B*H'*W' and
rows = kh*kw*c_per_array (DESIGN.md §3).

Shard-axis invariants (DESIGN.md §10): the trailing N axis of ``digits``
/ ``s_p`` / ``deq`` is the column-parallel shard axis — each output
column's full pipeline (MACs, ADC quantization, dequant, shift-and-add)
reads only that column's planes and scales, and both reduction dims live
inside the grid of ONE kernel invocation. ``kernels/ops`` exploits this:
on a multi-device serving mesh it calls this kernel once per column
shard under ``shard_map`` (scales sliced with their columns, ragged N
padded like the last bn block) and all-gathers only the final f32
output. Nothing in this module may introduce cross-column coupling
(e.g. column-normalized arithmetic) without breaking that contract.

Cell variation (DESIGN.md §8): ``variation_key``/``variation_std`` make
the kernel evaluate one Monte-Carlo device realization — the digit
operand is multiplied by log-normal noise drawn over its *unpadded
packed* shape (S, k_tiles, rows, N) before the pallas_call, so the same
``jax.random`` stream perturbs the same physical cell as on the emulate
path (that is the bit-exactness contract; in-kernel pltpu PRNG could not
reproduce ``jax.random.normal`` draws). The psum-in-VMEM fusion is
unchanged; the digit operand streams as float32 instead of int8 for the
duration of the noisy evaluation.

Block shapes (VMEM working set per step, bm=bn=128, rows=256, f32):
  a:      (bm, 1, rows)        128*256*4   = 128 KiB
  digits: (1, 1, rows, bn)     256*128*4   = 128 KiB (int8 in HBM, cast on load)
  scales: 2 x (1, 1, bn)                  ~= 1 KiB
  out:    (bm, bn)             128*128*4   =  64 KiB
comfortably inside the ~16 MiB VMEM budget; MXU dims are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nibble import unpack_nibbles
from repro.core.variation import perturb_digits, variation_wanted


def decode_digit_block(d, *, nibble: bool, groups: int) -> jnp.ndarray:
    """VMEM digit-block decode shared by the deploy kernel bodies.

    ``d``: a (rows_stored, bn) block — uint8 nibble pairs when ``nibble``
    (rows_stored = rows / 2, half-split pairing per group along the row
    axis; ``repro.core.nibble``), else int8/int4/float digits. Returns
    (rows, bn) float32."""
    if nibble:
        d = unpack_nibbles(d, groups=groups)
    return d.astype(jnp.float32)


def _adc_quantize(p, sp_ref, *, psum_bits: int):
    sp = jnp.maximum(sp_ref[0, 0, :].astype(jnp.float32), 1e-9)  # (bn,)
    if psum_bits == 1:
        return jnp.where(p >= 0, 1.0, -1.0) * sp[None, :]
    qn = float(-(2 ** (psum_bits - 1)))
    qp = float(2 ** (psum_bits - 1) - 1)
    return jnp.clip(jnp.round(p / sp[None, :]), qn, qp) * sp[None, :]


def _kernel(a_ref, d_ref, sp_ref, deq_ref, o_ref, *, psum_bits: int,
            psum_quant: bool, nibble: bool = False, groups: int = 1):
    t = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(jnp.logical_and(t == 0, s == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[:, 0, :].astype(jnp.float32)          # (bm, rows)
    d = decode_digit_block(d_ref[0, 0], nibble=nibble, groups=groups)
    p = jnp.dot(a, d, preferred_element_type=jnp.float32)  # (bm, bn) column MACs

    if psum_quant:
        p = jnp.round(p)    # integer-valued MACs: kill float roundoff
        p = _adc_quantize(p, sp_ref, psum_bits=psum_bits)

    deq = deq_ref[0, 0, :].astype(jnp.float32)      # (bn,)
    o_ref[...] += p * deq[None, :]


def _kernel_sparse(a_ref, d_ref, occ_ref, sp_ref, deq_ref, o_ref, *,
                   psum_bits: int, psum_quant: bool, nibble: bool = False,
                   groups: int = 1):
    """Occupancy-aware variant: ``occ_ref`` carries one byte per (split,
    array tile, column) — 0 means every cell of that column's digit plane
    is zero. A (bn-column) block whose planes are ALL unoccupied skips
    the MAC + ADC stage entirely; a block with any occupied column runs
    the **verbatim dense body** (no per-column masking — a mask between
    the multiply and the accumulate changes XLA's fusion and costs 1-ulp
    drift). Bit-exact with ``_kernel`` on the same operands
    (tests/test_sparse_skip.py):

      * under the sign ADC (psum_bits == 1) a zero plane still drives the
        dense path's comparator to +1, contributing ``+s_p * deq`` — the
        skipped-block branch reproduces that through the SAME expression
        graph as the dense body, with the dot replaced by its known
        result (+0.0), so compiler fusion cannot diverge;
      * for psum_bits > 1 (and psum_quant=False) a zero plane quantizes
        to 0 and contributes +0.0, which the skip reproduces because the
        f32 accumulator can never hold -0.0 (init is +0.0 and round-to-
        nearest never produces -0.0 from a +0.0 starting point).
    """
    t = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(jnp.logical_and(t == 0, s == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    occ = occ_ref[0, 0, :]                          # (bn,) uint8
    occupied = jnp.any(occ > 0)

    @pl.when(occupied)
    def _mac():
        a = a_ref[:, 0, :].astype(jnp.float32)
        d = decode_digit_block(d_ref[0, 0], nibble=nibble, groups=groups)
        p = jnp.dot(a, d, preferred_element_type=jnp.float32)
        if psum_quant:
            p = jnp.round(p)
            p = _adc_quantize(p, sp_ref, psum_bits=psum_bits)
        deq = deq_ref[0, 0, :].astype(jnp.float32)
        o_ref[...] += p * deq[None, :]

    if psum_quant and psum_bits == 1:
        # sign-ADC compensation for fully skipped blocks: the zero
        # plane's psum (+0.0) quantizes to +s_p on the dense path
        @pl.when(jnp.logical_not(occupied))
        def _comp():
            p = _adc_quantize(jnp.zeros(o_ref.shape, jnp.float32), sp_ref,
                              psum_bits=psum_bits)
            deq = deq_ref[0, 0, :].astype(jnp.float32)
            o_ref[...] += p * deq[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("psum_bits", "psum_quant", "nibble_groups", "block_m",
                     "block_n", "interpret"),
)
def cim_matmul_pallas(
    a_t: jnp.ndarray,      # (M, k_tiles, rows) integer-valued
    digits: jnp.ndarray,   # (S, k_tiles, rows, N); uint8 = nibble-packed
    s_p: jnp.ndarray,      # (S, k_tiles, N)
    deq: jnp.ndarray,      # (S, k_tiles, N)
    variation_key=None,    # optional PRNG key: one MC device realization
    variation_std=None,    # log-normal sigma (float or traced scalar)
    occ=None,              # optional (S, k_tiles, N) uint8 occupancy map
    *,
    psum_bits: int,
    psum_quant: bool = True,
    nibble_groups: int = 1,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    nibble = digits.dtype == jnp.uint8   # nibble-packed HBM planes (§14)
    if variation_wanted(variation_key, variation_std):
        # perturb BEFORE block padding: noise indices must match the
        # packed (unpadded) LOGICAL layout the emulate path perturbs (§8)
        # — nibble planes decode to that layout first, so a packed and a
        # dense artifact draw identical noise from the same key
        if nibble:
            digits = unpack_nibbles(digits, groups=nibble_groups)
            nibble = False
        digits = perturb_digits(digits, variation_key, variation_std)
    m, k_tiles, rows = a_t.shape
    n_split = digits.shape[0]
    n = digits.shape[-1]
    rows_d = digits.shape[2]             # stored rows: rows/2 when nibble
    assert rows_d == (rows // 2 if nibble else rows), \
        (digits.shape, a_t.shape, nibble)

    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        a_t = jnp.pad(a_t, ((0, pad_m), (0, 0), (0, 0)))
    if pad_n:
        digits = jnp.pad(digits, ((0, 0), (0, 0), (0, 0), (0, pad_n)))
        s_p = jnp.pad(s_p, ((0, 0), (0, 0), (0, pad_n)), constant_values=1.0)
        deq = jnp.pad(deq, ((0, 0), (0, 0), (0, pad_n)))
        if occ is not None:
            occ = jnp.pad(occ, ((0, 0), (0, 0), (0, pad_n)))  # dead: skip
    mp, np_ = m + pad_m, n + pad_n

    grid = (mp // bm, np_ // bn, k_tiles, n_split)
    col_spec = pl.BlockSpec((1, 1, bn), lambda i, j, t, s: (s, t, j))
    in_specs = [
        pl.BlockSpec((bm, 1, rows), lambda i, j, t, s: (i, t, 0)),
        pl.BlockSpec((1, 1, rows_d, bn), lambda i, j, t, s: (s, t, 0, j)),
    ]
    if occ is None:
        body = _kernel
        args = (a_t, digits, s_p, deq)
    else:
        body = _kernel_sparse
        args = (a_t, digits, occ.astype(jnp.uint8), s_p, deq)
        in_specs.append(col_spec)        # occupancy rides a scale-like spec
    in_specs += [col_spec, col_spec]
    out = pl.pallas_call(
        functools.partial(body, psum_bits=psum_bits, psum_quant=psum_quant,
                          nibble=nibble, groups=nibble_groups),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# batched expert banks (MoE dispatch)
# ---------------------------------------------------------------------------

def _experts_kernel(a_ref, d_ref, sp_ref, deq_ref, o_ref, *, psum_bits: int,
                    psum_quant: bool):
    t = pl.program_id(3)
    s = pl.program_id(4)

    @pl.when(jnp.logical_and(t == 0, s == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0, :, 0, :].astype(jnp.float32)        # (bm, rows)
    d = d_ref[0, 0, 0].astype(jnp.float32)           # (rows, bn)
    p = jnp.dot(a, d, preferred_element_type=jnp.float32)

    if psum_quant:
        p = jnp.round(p)
        sp = jnp.maximum(sp_ref[0, 0, 0, :].astype(jnp.float32), 1e-9)
        if psum_bits == 1:
            p = jnp.where(p >= 0, 1.0, -1.0) * sp[None, :]
        else:
            qn = float(-(2 ** (psum_bits - 1)))
            qp = float(2 ** (psum_bits - 1) - 1)
            p = jnp.clip(jnp.round(p / sp[None, :]), qn, qp) * sp[None, :]

    deq = deq_ref[0, 0, 0, :].astype(jnp.float32)
    o_ref[...] += (p * deq[None, :])[None]


@functools.partial(
    jax.jit,
    static_argnames=("psum_bits", "psum_quant", "block_m", "block_n",
                     "interpret"),
)
def cim_matmul_experts_pallas(
    a_t: jnp.ndarray,      # (E, C, k_tiles, rows) integer-valued
    digits: jnp.ndarray,   # (E, S, k_tiles, rows, N)
    s_p: jnp.ndarray,      # (E, S, k_tiles, N)
    deq: jnp.ndarray,      # (E, S, k_tiles, N)
    *,
    psum_bits: int,
    psum_quant: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Whole-bank MoE variant: all E experts' capacity buffers through ONE
    pallas_call with the expert index as the leading (slowest) grid
    dimension, instead of ``lax.map`` issuing E sequential calls
    (``pallas_call`` has no batching rule, so vmap can't do this).

    Per output block the (t, s) accumulation order, block shapes and
    last-block padding are IDENTICAL to ``cim_matmul_pallas`` on one
    expert's (C, K) slice — the batched path is bit-exact with the
    ``lax.map`` fallback, which is what keeps the model-zoo deploy-vs-
    emulate parity gates green. Variation injection is not plumbed here:
    the packed expert dispatch (``models.layers._expert_matmul``) never
    injects per-call noise (bank noise is baked at pack time), and
    callers needing it take the ``lax.map`` path.
    """
    e, m, k_tiles, rows = a_t.shape
    n_split = digits.shape[1]
    n = digits.shape[-1]

    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        a_t = jnp.pad(a_t, ((0, 0), (0, pad_m), (0, 0), (0, 0)))
    if pad_n:
        digits = jnp.pad(digits,
                         ((0, 0), (0, 0), (0, 0), (0, 0), (0, pad_n)))
        s_p = jnp.pad(s_p, ((0, 0), (0, 0), (0, 0), (0, pad_n)),
                      constant_values=1.0)
        deq = jnp.pad(deq, ((0, 0), (0, 0), (0, 0), (0, pad_n)))
    mp, np_ = m + pad_m, n + pad_n

    grid = (e, mp // bm, np_ // bn, k_tiles, n_split)
    out = pl.pallas_call(
        functools.partial(_experts_kernel, psum_bits=psum_bits,
                          psum_quant=psum_quant),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, 1, rows),
                         lambda ei, i, j, t, s: (ei, i, t, 0)),
            pl.BlockSpec((1, 1, 1, rows, bn),
                         lambda ei, i, j, t, s: (ei, s, t, 0, j)),
            pl.BlockSpec((1, 1, 1, bn),
                         lambda ei, i, j, t, s: (ei, s, t, j)),
            pl.BlockSpec((1, 1, 1, bn),
                         lambda ei, i, j, t, s: (ei, s, t, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda ei, i, j, t, s: (ei, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, mp, np_), jnp.float32),
        interpret=interpret,
    )(a_t, digits, s_p, deq)
    return out[:, :m, :n]
