"""Pure-jnp oracles for the Pallas kernels.

These define the exact arithmetic the kernels must reproduce; tests sweep
shapes/dtypes and assert allclose against them.
"""
from __future__ import annotations

import jax.numpy as jnp


def adc_quantize_ref(p: jnp.ndarray, s_p: jnp.ndarray, psum_bits: int) -> jnp.ndarray:
    """ADC model: uniform mid-rise quantization of a partial sum at scale
    s_p, clipped to the signed psum_bits range. psum_bits == 1 is the
    binary (sign) ADC-less mode. Partial sums are integer-valued (int x
    int MACs); snapping to the grid first makes tie-breaking summation-
    order independent."""
    p = jnp.round(p)
    s_p = jnp.maximum(s_p, 1e-9)
    if psum_bits == 1:
        return jnp.where(p >= 0, 1.0, -1.0) * s_p
    qn = -(2 ** (psum_bits - 1))
    qp = 2 ** (psum_bits - 1) - 1
    return jnp.clip(jnp.round(p / s_p), qn, qp) * s_p


def cim_matmul_ref(
    a_t: jnp.ndarray,      # (M, k_tiles, rows)    integer-valued float
    digits: jnp.ndarray,   # (S, k_tiles, rows, N) int8 or float digits
    s_p: jnp.ndarray,      # (S, k_tiles, N)       psum (ADC) scales
    deq: jnp.ndarray,      # (S, k_tiles, N)       fused dequant scales
    *,
    psum_bits: int,
    psum_quant: bool = True,
) -> jnp.ndarray:
    """CIM matmul oracle: per-(split, array) integer MACs, ADC quantization
    of each column partial sum, fused dequant, shift-and-add. Returns
    (M, N) float32."""
    psum = jnp.einsum(
        "mtr,strn->mstn",
        a_t.astype(jnp.float32),
        digits.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if psum_quant:
        psum = adc_quantize_ref(psum, s_p[None], psum_bits)
    return jnp.einsum("mstn,stn->mn", psum, deq.astype(jnp.float32))


def lsq_fake_quant_ref(x, s, qn: float, qp: float):
    s = jnp.maximum(s, 1e-9)
    return jnp.clip(jnp.round(x / s), qn, qp) * s
