"""Pure-jnp oracles for the Pallas kernels.

These define the exact arithmetic the kernels must reproduce; tests sweep
shapes/dtypes and assert allclose against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_quantize_ref(p: jnp.ndarray, s_p: jnp.ndarray, psum_bits: int) -> jnp.ndarray:
    """ADC model: uniform mid-rise quantization of a partial sum at scale
    s_p, clipped to the signed psum_bits range. psum_bits == 1 is the
    binary (sign) ADC-less mode. Partial sums are integer-valued (int x
    int MACs); snapping to the grid first makes tie-breaking summation-
    order independent."""
    p = jnp.round(p)
    s_p = jnp.maximum(s_p, 1e-9)
    if psum_bits == 1:
        return jnp.where(p >= 0, 1.0, -1.0) * s_p
    qn = -(2 ** (psum_bits - 1))
    qp = 2 ** (psum_bits - 1) - 1
    return jnp.clip(jnp.round(p / s_p), qn, qp) * s_p


def cim_matmul_ref(
    a_t: jnp.ndarray,      # (M, k_tiles, rows)    integer-valued float
    digits: jnp.ndarray,   # (S, k_tiles, rows, N) int8 or float digits
    s_p: jnp.ndarray,      # (S, k_tiles, N)       psum (ADC) scales
    deq: jnp.ndarray,      # (S, k_tiles, N)       fused dequant scales
    *,
    psum_bits: int,
    psum_quant: bool = True,
) -> jnp.ndarray:
    """CIM matmul oracle: per-(split, array) integer MACs, ADC quantization
    of each column partial sum, fused dequant, shift-and-add. Returns
    (M, N) float32."""
    psum = jnp.einsum(
        "mtr,strn->mstn",
        a_t.astype(jnp.float32),
        digits.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if psum_quant:
        psum = adc_quantize_ref(psum, s_p[None], psum_bits)
    return jnp.einsum("mstn,stn->mn", psum, deq.astype(jnp.float32))


def cim_matmul_adc_free_ref(
    a_t: jnp.ndarray,      # (M, k_tiles, rows)    integer-valued float
    digits: jnp.ndarray,   # (S, k_tiles, rows, N) int8 or float digits
    deq: jnp.ndarray,      # (S, k_tiles, N)       fused dequant scales
) -> jnp.ndarray:
    """ADC-free CIM matmul oracle (HCiM-style hardware, DESIGN.md §13):
    per-(split, array) integer MACs leave the array exact — partial sums
    are accumulated digitally, so there is no ADC quantization stage and
    no s_p operand. Returns (M, N) float32."""
    psum = jnp.einsum(
        "mtr,strn->mstn",
        a_t.astype(jnp.float32),
        digits.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    psum = jnp.round(psum)  # same integer snap as the ADC oracle
    return jnp.einsum("mstn,stn->mn", psum, deq.astype(jnp.float32))


def lsq_fake_quant_ref(x, s, qn: float, qp: float):
    s = jnp.maximum(s, 1e-9)
    return jnp.clip(jnp.round(x / s), qn, qp) * s


def conv_pads(h: int, w: int, kh: int, kw: int, stride: int, padding):
    """Resolve a conv padding spec to explicit ((lo,hi),(lo,hi)) pairs,
    identical to what XLA's conv_general_dilated computes for the same
    string — the deploy patch path must agree with the emulate conv."""
    if isinstance(padding, str):
        pads = jax.lax.padtype_to_pads((h, w), (kh, kw), (stride, stride),
                                       padding.upper())
        return tuple((int(lo), int(hi)) for lo, hi in pads)
    return tuple((int(lo), int(hi)) for lo, hi in padding)


def extract_conv_patches(
    a: jnp.ndarray,        # (B, H, W, C)
    kh: int, kw: int,
    stride: int,
    padding,
    k_tiles: int,
    c_per_array: int,
) -> jnp.ndarray:
    """Stretched-kernel patch extraction (paper §III-C, DESIGN.md §3).

    Returns (B, H', W', k_tiles, kh*kw*c_per_array): for every output
    position, tile t's row block holds exactly the activations its CIM
    array's stretched kernels see, flattened tap-major (dh, dw, c). This
    is NOT generic im2col — the contraction axis is tiled by the paper's
    ``c_per_array = floor(rows / K^2)`` rule so channel slices never
    straddle an array boundary. Channels are zero-padded to
    ``k_tiles * c_per_array`` (matching the emulate path's padding).
    """
    b, h, w, c = a.shape
    pads = conv_pads(h, w, kh, kw, stride, padding)
    c_pad = k_tiles * c_per_array - c
    a = jnp.pad(a, ((0, 0), pads[0], pads[1], (0, c_pad)))
    hp = h + pads[0][0] + pads[0][1]
    wp = w + pads[1][0] + pads[1][1]
    ho = (hp - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    taps = []
    for dh in range(kh):
        for dw in range(kw):
            taps.append(jax.lax.slice(
                a, (0, dh, dw, 0),
                (b, dh + (ho - 1) * stride + 1,
                 dw + (wo - 1) * stride + 1, a.shape[3]),
                (1, stride, stride, 1)))
    p = jnp.stack(taps, axis=3)                     # (B,H',W',taps,kt*cpa)
    p = p.reshape(b, ho, wo, kh * kw, k_tiles, c_per_array)
    p = jnp.transpose(p, (0, 1, 2, 4, 3, 5))        # (B,H',W',kt,taps,cpa)
    return p.reshape(b, ho, wo, k_tiles, kh * kw * c_per_array)


def cim_conv_ref(
    a_int: jnp.ndarray,    # (B, H, W, C_in) integer-valued codes
    digits: jnp.ndarray,   # (S, k_tiles, kh*kw*cpa, C_out)
    s_p: jnp.ndarray,      # (S, k_tiles, C_out)
    deq: jnp.ndarray,      # (S, k_tiles, C_out)
    *,
    kh: int, kw: int,
    stride: int,
    padding,
    c_per_array: int,
    psum_bits: int,
    psum_quant: bool = True,
) -> jnp.ndarray:
    """CIM conv oracle: stretched-kernel patches, then the matmul oracle
    per output position. Returns (B, H', W', C_out) float32."""
    k_tiles = digits.shape[1]
    a_t = extract_conv_patches(a_int.astype(jnp.float32), kh, kw, stride,
                               padding, k_tiles, c_per_array)
    b, ho, wo = a_t.shape[:3]
    out = cim_matmul_ref(
        a_t.reshape(b * ho * wo, k_tiles, kh * kw * c_per_array),
        digits, s_p, deq, psum_bits=psum_bits, psum_quant=psum_quant)
    return out.reshape(b, ho, wo, digits.shape[-1])
