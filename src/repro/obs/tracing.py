"""Lightweight tracing spans (DESIGN.md §12).

A span measures one host-side region — a request's prefill, one decode
step, a recalibration fit — and records on exit:

* a duration observation into ``<name>.seconds`` on the tracer's
  registry (so spans and metrics share one export path), and
* a ``span`` event in the registry's event log carrying the span's
  name, duration, attributes and its parent span's name.

Nesting is tracked per-thread with a plain stack: a span opened inside
another records that span as its parent, which is all the structure the
serving engine needs (request -> prefill -> per-layer would be the next
refinement). Spans never trace into jit — they time the host-side
dispatch like any external observer would.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry


@dataclasses.dataclass
class SpanRecord:
    """One finished span. ``duration`` in seconds; ``parent`` is the
    enclosing span's name (None at top level)."""

    name: str
    t_start: float
    duration: float
    parent: Optional[str] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Tracer:
    """Span factory bound to one ``MetricsRegistry``.

    >>> tracer = Tracer(registry)
    >>> with tracer.span("serve.prefill", rid=3):
    ...     ...   # registry histogram "serve.prefill.seconds" observes
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 max_spans: int = 8192):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.spans: List[SpanRecord] = []
        self._max_spans = max_spans
        self._local = threading.local()

    def _stack(self) -> List[str]:
        if not hasattr(self._local, "stack"):
            self._local.stack = []
        return self._local.stack

    @contextmanager
    def span(self, name: str, **attrs: Any):
        stack = self._stack()
        parent = stack[-1] if stack else None
        stack.append(name)
        t0 = time.perf_counter()
        ts = time.time()
        try:
            yield
        finally:
            dur = time.perf_counter() - t0
            stack.pop()
            rec = SpanRecord(name=name, t_start=ts, duration=dur,
                             parent=parent, attrs=dict(attrs))
            self.spans.append(rec)
            if len(self.spans) > self._max_spans:
                del self.spans[: len(self.spans) - self._max_spans]
            self.registry.histogram(f"{name}.seconds").observe(dur)
            self.registry.log_event("span", name=name, duration=dur,
                                    parent=parent, **attrs)
