"""Canonical metric names (DESIGN.md §12).

Every metric the serving engine, kernels, and benches emit is named
here, not at the emission site — the DESIGN.md §12 table is checked
against this module by ``tools/check_metrics.py`` (CI docs job), so a
renamed or deleted metric fails the build instead of silently breaking
a dashboard.

Naming scheme: dot-separated ``<plane>.<subsystem>.<what>``; histograms
of durations end in ``.seconds``. Prometheus exposition sanitizes dots
to underscores (``MetricsRegistry.to_prometheus``).
"""
from __future__ import annotations

# -- serving plane (recorded by repro.serve.engine.ServingEngine) -----------

#: counter: requests accepted by ``submit()``
REQUESTS_SUBMITTED = "serve.requests.submitted"
#: counter: requests finished and retired from their slot
REQUESTS_COMPLETED = "serve.requests.completed"
#: counter: decode tokens emitted across all slots
TOKENS_GENERATED = "serve.tokens.generated"
#: counter: in-service column-scale recalibrations landed
#: (``ServingEngine.recalibrate`` / eval/recalibrate.py)
RECALIBRATIONS = "serve.recalibrations"
#: gauge: requests waiting in the admission queue
QUEUE_DEPTH = "serve.queue.depth"
#: gauge: slots currently serving a live request
ACTIVE_SLOTS = "serve.slots.active"
#: histogram: submit -> admission wait per request
QUEUE_WAIT_SECONDS = "serve.request.queue_wait.seconds"
#: histogram: submit -> last token per request
REQUEST_LATENCY_SECONDS = "serve.request.latency.seconds"
#: histogram: per-request prefill span (all prompt tokens)
PREFILL_SECONDS = "serve.prefill.seconds"
#: histogram: one engine decode step (all active slots advance one token)
DECODE_STEP_SECONDS = "serve.decode.step.seconds"

# -- CIM / ADC plane (recorded by repro.obs.adc, fed from the kernels) ------

#: counter: kernel invocations folded by the sampled collector
ADC_SAMPLES = "cim.adc.samples"
#: counter: ADC conversions covered by the folded samples
ADC_CONVERSIONS = "cim.adc.conversions"
#: counter: conversions whose partial sum clipped at the ADC range
ADC_SATURATED = "cim.adc.saturated"
#: histogram: per-column saturation rate, one observation per column
#: per folded sample (the paper-native drift signal)
ADC_COL_SATURATION_RATE = "cim.adc.col_saturation_rate"
#: histogram: per-column mean ADC range occupancy |q|/q_max
ADC_OCCUPANCY = "cim.adc.occupancy"
