"""repro.obs — the telemetry plane (DESIGN.md §12).

Three pieces, all host-side and dependency-free:

* ``metrics``: a ``MetricsRegistry`` of counters/gauges/histograms with
  JSON snapshot + reset, a JSONL event log, and Prometheus text
  exposition. Histogram percentiles are exact (numpy-compatible
  interpolation over raw samples).
* ``tracing``: ``Tracer``/``SpanRecord`` — nested host-side spans that
  record durations into the registry and events into its log.
* ``adc``: the sampled per-column ADC saturation collector the kernel
  wrappers and emulate forwards feed (``cim.adc.*`` metrics) — the
  paper-native drift signal, off by default, zero-overhead when
  disarmed.

Canonical metric names live in ``names`` and nowhere else;
``tools/check_metrics.py`` holds DESIGN.md §12 to them.
"""
from . import adc, names
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import SpanRecord, Tracer

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "SpanRecord", "Tracer", "adc", "names"]
