"""Per-column ADC saturation counters (DESIGN.md §12).

The paper's core claim is that column-wise partial-sum scales keep
low-bit ADC quantization accurate; the leading *production* indicator
that a chip (or its calibration) is going bad is therefore the fraction
of partial sums that clip at the ADC range, per physical column. This
module collects exactly that signal from the running forwards:

* **emulate** materializes every partial sum anyway (for LSQ
  gradients), so its counters are exact — every conversion of every
  step is counted while armed.
* **deploy/ref** never materialize the partial-sum tensor (that is the
  point of the fused kernel), so the kernel wrappers
  (``kernels/ops.cim_matmul`` / ``cim_conv``) add a *side-output* when
  armed: the psums are recomputed by a jnp einsum next to the kernel
  call and reduced to per-column counts. The main output is untouched —
  bit-exact with the un-instrumented path (tests assert) — and when the
  collector is disarmed the side computation is absent from the trace
  entirely, so the disabled path costs zero.

Arming is a **trace-time** decision: ``enable()`` before the first
forward (or engine build); functions jitted while disarmed carry no
instrumentation until they retrace. Disarming is effective immediately
even for already-traced functions — the host-side fold checks
``enabled()`` per callback. ``every_n`` decimates host-side folding
(callback bookkeeping + histogram growth); the traced side computation
runs per armed invocation, which is why the collector is off by
default.

Counts cross the device boundary with ``jax.debug.callback``; callbacks
are asynchronous, so call ``sync()`` (an effects barrier) before
reading ``summary()``/``totals()`` at a point where exact totals
matter.
"""
from __future__ import annotations

import functools
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import names
from .metrics import MetricsRegistry


class _AdcState:
    """Module-level collector state (one serving process, one chip)."""

    def __init__(self):
        self.enabled = False
        self.every_n = 1
        self.registry: Optional[MetricsRegistry] = None
        self.calls = 0                  # armed kernel invocations seen
        self.saturated_total = 0        # folded clipped conversions
        self.conversions_total = 0      # folded conversions
        self.worst_col_rate = 0.0       # max per-column rate ever folded
        self.last_col_rates: Optional[np.ndarray] = None
        self.last_col_occupancy: Optional[np.ndarray] = None


_STATE = _AdcState()


def enable(registry: Optional[MetricsRegistry] = None,
           every_n: int = 1) -> MetricsRegistry:
    """Arm the collector. Must run before the instrumented functions
    trace (see module docstring). Returns the sink registry."""
    if every_n < 1:
        raise ValueError(f"every_n must be >= 1, got {every_n}")
    _STATE.enabled = True
    _STATE.every_n = every_n
    _STATE.registry = registry if registry is not None else MetricsRegistry()
    return _STATE.registry


def disable() -> None:
    """Disarm. Effective immediately, even for stale traces (the fold
    callback checks this flag host-side)."""
    _STATE.enabled = False


def enabled() -> bool:
    return _STATE.enabled


def reset() -> None:
    """Zero the collector's own totals (the sink registry is the
    caller's; reset it separately if wanted)."""
    _STATE.calls = 0
    _STATE.saturated_total = 0
    _STATE.conversions_total = 0
    _STATE.worst_col_rate = 0.0
    _STATE.last_col_rates = None
    _STATE.last_col_occupancy = None


@contextmanager
def sampled(registry: Optional[MetricsRegistry] = None, every_n: int = 1):
    """Scoped arming for benches and tests: arm, yield the registry,
    disarm and reset on exit."""
    reg = enable(registry, every_n)
    try:
        yield reg
    finally:
        disable()
        reset()


def sync() -> None:
    """Wait for in-flight fold callbacks (jax effects barrier)."""
    jax.effects_barrier()


def totals() -> Tuple[int, int]:
    """(saturated, conversions) folded so far — the engine derives its
    per-step clip-rate drift statistic from deltas of these."""
    return _STATE.saturated_total, _STATE.conversions_total


def summary() -> Dict[str, object]:
    """JSON-safe roll-up for ``engine.metrics()`` / the load bench."""
    sat, conv = _STATE.saturated_total, _STATE.conversions_total
    return {
        "enabled": _STATE.enabled,
        "every_n": _STATE.every_n,
        "kernel_invocations": _STATE.calls,
        "samples_folded": _STATE.calls and (
            (_STATE.calls + _STATE.every_n - 1) // _STATE.every_n),
        "conversions": conv,
        "saturated": sat,
        "clip_rate": (sat / conv) if conv else 0.0,
        "worst_col_rate": _STATE.worst_col_rate,
    }


# ---------------------------------------------------------------------------
# the measurement itself
# ---------------------------------------------------------------------------

def saturation_stats(psum: jnp.ndarray, s_p: jnp.ndarray, psum_bits: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-column (last-axis) ADC statistics of a partial-sum tensor.

    psum (..., N) against scales s_p broadcastable to it. Returns
    ``(saturated, occupancy)``: clipped-conversion counts (N,) int32 and
    mean |q|/q_max range occupancy (N,) float32. ``psum_bits == 1`` is
    the sign ADC — it cannot clip and always occupies full range."""
    n = psum.shape[-1]
    if psum_bits < 2:
        return (jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.float32))
    qn = float(-(2 ** (psum_bits - 1)))
    qp = float(2 ** (psum_bits - 1) - 1)
    q = jnp.round(jnp.round(psum.astype(jnp.float32))
                  / jnp.maximum(s_p.astype(jnp.float32), 1e-9))
    axes = tuple(range(psum.ndim - 1))
    sat = jnp.sum(((q < qn) | (q > qp)).astype(jnp.int32), axis=axes)
    occ = jnp.mean(jnp.abs(jnp.clip(q, qn, qp)) / qp, axis=axes)
    return sat, occ


def _fold(sat: np.ndarray, occ: np.ndarray, *, conv_per_col: int) -> None:
    """Host-side sink for one kernel invocation's per-column counts.
    Decimation (``every_n``) and the disarm check both live here so a
    stale armed trace stops reporting the moment ``disable()`` runs."""
    st = _STATE
    if not st.enabled or st.registry is None:
        return
    st.calls += 1
    if (st.calls - 1) % st.every_n:
        return
    sat = np.asarray(sat, np.int64)
    occ = np.asarray(occ, np.float64)
    n = int(sat.shape[0])
    conv = conv_per_col * n
    st.saturated_total += int(sat.sum())
    st.conversions_total += conv
    rates = sat / float(conv_per_col)
    st.worst_col_rate = max(st.worst_col_rate, float(rates.max(initial=0.0)))
    st.last_col_rates = rates
    st.last_col_occupancy = occ
    reg = st.registry
    reg.counter(names.ADC_SAMPLES).inc()
    reg.counter(names.ADC_CONVERSIONS).inc(conv)
    reg.counter(names.ADC_SATURATED).inc(int(sat.sum()))
    h_rate = reg.histogram(names.ADC_COL_SATURATION_RATE)
    h_occ = reg.histogram(names.ADC_OCCUPANCY)
    for r, o in zip(rates, occ):
        h_rate.observe(r)
        h_occ.observe(o)


def record(psum: jnp.ndarray, s_p: jnp.ndarray, psum_bits: int) -> None:
    """Traced side-output: reduce ``psum`` to per-column counts and ship
    them host-side. Call ONLY under ``enabled()`` (trace-time check —
    the caller's ``if adc.enabled():`` is what makes the disabled path
    free) and only when the config actually quantizes partial sums."""
    sat, occ = saturation_stats(psum, s_p, psum_bits)
    conv_per_col = int(np.prod(psum.shape[:-1]))
    jax.debug.callback(functools.partial(_fold, conv_per_col=conv_per_col),
                       sat, occ)
