"""Metrics registry: counters, gauges, histograms (DESIGN.md §12).

Plain host-side state — nothing here traces or allocates on device. The
registry is the single sink for the serving plane (``serve.engine``),
the kernel ADC counters (``repro.obs.adc``) and the load bench; one
``snapshot()`` (JSON-safe dict) or ``to_prometheus()`` (text exposition)
call exports everything.

Histograms keep raw observations (capped — see ``Histogram``) so
percentiles are computed exactly at snapshot time with numpy-compatible
linear interpolation, rather than approximated from fixed buckets; the
load bench's p50/p99 come straight from these.

``log_event`` appends structured events (request lifecycle, spans,
recalibrations) to an in-memory ring and, when the registry was built
with ``event_log_path``, to a JSONL file — one JSON object per line,
each stamped with ``ts`` (epoch seconds) and ``kind``.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Any, Dict, List, Optional


class Counter:
    """Monotonic counter. ``inc`` only; reset via the registry."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-value-wins instantaneous measurement."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Raw-sample histogram with exact (numpy-interpolation) percentiles.

    Observations are kept verbatim up to ``max_samples``; past the cap
    the stream is decimated — every ``stride``-th observation is kept
    and the stride doubles each time the buffer refills — so memory is
    bounded while ``count``/``sum`` stay exact and percentiles degrade
    gracefully to a uniform subsample of the stream.
    """

    __slots__ = ("name", "count", "sum", "min", "max",
                 "_values", "_max_samples", "_stride", "_skip")

    def __init__(self, name: str, max_samples: int = 65536):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._values: List[float] = []
        self._max_samples = max_samples
        self._stride = 1
        self._skip = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if self._skip:
            self._skip -= 1
            return
        self._skip = self._stride - 1
        self._values.append(v)
        if len(self._values) >= self._max_samples:
            self._values = self._values[::2]
            self._stride *= 2

    def percentile(self, q: float) -> float:
        """q in [0, 100]; linear interpolation between closest ranks —
        the same convention as ``numpy.percentile``'s default."""
        if not self._values:
            return math.nan
        vs = sorted(self._values)
        rank = (q / 100.0) * (len(vs) - 1)
        lo = int(math.floor(rank))
        hi = int(math.ceil(rank))
        if lo == hi:
            return vs[lo]
        return vs[lo] + (vs[hi] - vs[lo]) * (rank - lo)

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.sum / self.count,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


def _sanitize(name: str) -> str:
    """Prometheus metric names allow [a-zA-Z0-9_:]; dots become
    underscores (``serve.queue.depth`` -> ``serve_queue_depth``)."""
    return "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)


class MetricsRegistry:
    """Get-or-create registry of named metrics + structured event log.

    Thread-safe for creation (the engine and a metrics exporter may race
    on first touch); individual metric updates are GIL-atomic appends /
    adds, which is the granularity this plane needs.
    """

    def __init__(self, event_log_path: Optional[str] = None,
                 max_events: int = 8192):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._events: List[Dict[str, Any]] = []
        self._max_events = max_events
        self.event_log_path = event_log_path
        self._event_file = None
        if event_log_path:
            self._event_file = open(event_log_path, "a", encoding="utf-8")

    # -- metric accessors ---------------------------------------------------

    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self._counters:
                self._counters[name] = Counter(name)
            return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._gauges[name] = Gauge(name)
            return self._gauges[name]

    def histogram(self, name: str, max_samples: int = 65536) -> Histogram:
        with self._lock:
            if name not in self._histograms:
                self._histograms[name] = Histogram(name, max_samples)
            return self._histograms[name]

    # -- events -------------------------------------------------------------

    def log_event(self, kind: str, **fields: Any) -> Dict[str, Any]:
        ev = {"ts": time.time(), "kind": kind, **fields}
        self._events.append(ev)
        if len(self._events) > self._max_events:
            del self._events[: len(self._events) - self._max_events]
        if self._event_file is not None:
            self._event_file.write(json.dumps(ev) + "\n")
            self._event_file.flush()
        return ev

    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        if kind is None:
            return list(self._events)
        return [e for e in self._events if e["kind"] == kind]

    # -- export -------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe view of every metric. Percentiles are computed here
        (from the raw samples), so the snapshot is self-contained."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: g.value for n, g in self._gauges.items()},
            "histograms": {n: h.summary()
                           for n, h in self._histograms.items()},
        }

    def reset(self) -> None:
        """Zero every metric and drop buffered events (the JSONL file, if
        any, is append-only and survives). Metric objects handed out
        earlier stay registered but restart from empty."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0
            for g in self._gauges.values():
                g.value = 0.0
            for name, h in list(self._histograms.items()):
                self._histograms[name] = Histogram(name, h._max_samples)
            self._events.clear()

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4): counters and
        gauges verbatim, histograms as summaries with p50/p90/p99
        quantiles plus ``_sum``/``_count``."""
        lines: List[str] = []
        for n, c in sorted(self._counters.items()):
            pn = _sanitize(n)
            lines += [f"# TYPE {pn} counter", f"{pn} {c.value}"]
        for n, g in sorted(self._gauges.items()):
            pn = _sanitize(n)
            lines += [f"# TYPE {pn} gauge", f"{pn} {g.value}"]
        for n, h in sorted(self._histograms.items()):
            pn = _sanitize(n)
            lines.append(f"# TYPE {pn} summary")
            if h.count:
                for q in ("0.5", "0.9", "0.99"):
                    val = h.percentile(float(q) * 100)
                    lines.append(f'{pn}{{quantile="{q}"}} {val}')
            lines.append(f"{pn}_sum {h.sum}")
            lines.append(f"{pn}_count {h.count}")
        return "\n".join(lines) + "\n"
