"""int8 gradient compression with error feedback.

Data-parallel gradient synchronization as reduce-scatter (f32, exact) +
int8 all-gather: each device averages its shard exactly, quantizes it to
int8 with a per-shard scale, and all-gathers the compressed bytes — 4x
fewer all-gather bytes than f32 (2x vs bf16). The local quantization
residual is carried in an error-feedback buffer and added to the next
step's gradient, which keeps SGD/Adam convergence unbiased in practice
(Karimireddy et al. 2019).

Exposed as pure functions usable inside shard_map (production path) and as
a single-device fallback (identity sync) so the trainer is mesh-agnostic.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g: jnp.ndarray, ef: jnp.ndarray, axis_name: str
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: synchronize one gradient leaf across ``axis_name``
    with int8 compression + error feedback. Returns (g_synced, ef_new)."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    flat = g.reshape(-1).astype(jnp.float32) + ef.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard_len = flat.shape[0] // n
    # exact reduce-scatter: every device ends up with the mean of its shard
    shards = flat.reshape(n, shard_len)
    my_shard = jax.lax.psum_scatter(shards, axis_name, scatter_dimension=0,
                                    tiled=False) / n
    # compress my shard, all-gather compressed
    q, scale = quantize_int8(my_shard)
    q_all = jax.lax.all_gather(q, axis_name)                  # (n, shard) int8
    s_all = jax.lax.all_gather(scale, axis_name)              # (n,)
    synced = (q_all.astype(jnp.float32) * s_all[:, None]).reshape(-1)
    # local error feedback: what my shard lost in quantization, scattered
    # back to this device's region of the flat gradient
    err_local = my_shard - dequantize_int8(q, scale)
    ef_flat = jnp.zeros_like(flat)
    ef_flat = jax.lax.dynamic_update_slice(ef_flat, err_local,
                                           (idx * shard_len,))
    if pad:
        synced = synced[:-pad]
        ef_flat = ef_flat[:-pad]
    return synced.reshape(g.shape).astype(g.dtype), ef_flat.reshape(g.shape)


def compressed_psum_tree(grads, ef_state, axis_name: str):
    """Apply compressed_psum_leaf across a gradient pytree."""
    out = jax.tree.map(
        lambda g, e: compressed_psum_leaf(g, e, axis_name), grads, ef_state)
    synced = jax.tree.map(lambda o: o[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return synced, new_ef


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(params) -> float:
    """Collective-byte ratio vs f32 all-reduce (for the roofline ledger):
    RS stays f32 (exact) but AG moves int8 + one f32 scale per shard."""
    total = sum(l.size for l in jax.tree.leaves(params))
    f32_bytes = 2 * 4 * total            # RS + AG at f32
    comp_bytes = 4 * total + 1 * total   # RS f32 + AG int8 (scales ~0)
    return comp_bytes / f32_bytes
