from .trainer import lm_loss_fn, make_train_step

__all__ = ["lm_loss_fn", "make_train_step"]
