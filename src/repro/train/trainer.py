"""Training step builder: loss, microbatch gradient accumulation (bounded
activation memory at 1M-token global batches), optimizer wiring, optional
int8-compressed data-parallel gradient sync (shard_map path).

The returned ``train_step(params, opt_state, batch)`` is a pure function:
jit/pjit it with param shardings from the launcher; donate params and
opt_state for in-place updates.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.registry import ModelFns
from repro.optim.optimizer import make_optimizer
from repro.optim.schedule import cosine_warmup


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  label_smoothing: float = 0.0) -> jnp.ndarray:
    """Mean CE over valid positions; logits promoted to f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = logz - gold
    if label_smoothing > 0:
        ce = ((1 - label_smoothing) * ce
              + label_smoothing * (logz - logits.mean(axis=-1)))
    if mask is None:
        return ce.mean()
    mask = mask.astype(jnp.float32)
    return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss_fn(model: ModelFns, cfg: ModelConfig):
    """Next-token loss for every family (llava prepends patch tokens and
    masks them; whisper conditions on frame embeddings)."""
    def loss(params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        extra = batch.get("frontend")
        logits = model.forward(params, inp, cfg, extra)
        if cfg.family == "llava" and extra is not None:
            logits = logits[:, extra.shape[1]:]
        return cross_entropy(logits, labels)
    return loss


def make_train_step(model: ModelFns, cfg: ModelConfig, run: RunConfig,
                    loss_fn: Optional[Callable] = None):
    """Returns (init_state, train_step).

    init_state(params) -> opt_state
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    opt = make_optimizer(run.optimizer)
    state_dtype = jnp.bfloat16 if run.opt_state_dtype == "bfloat16" else jnp.float32
    loss_fn = loss_fn or lm_loss_fn(model, cfg)

    def init_state(params):
        return opt.init(params, state_dtype)

    def grads_of(params, batch):
        if run.accum_steps <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # microbatch accumulation: scan over leading micro dim
        def split(x):
            b = x.shape[0]
            assert b % run.accum_steps == 0, (b, run.accum_steps)
            return x.reshape(run.accum_steps, b // run.accum_steps, *x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + l, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if run.accum_unroll:
            carry = (0.0, g0)
            for i in range(run.accum_steps):
                carry, _ = body(carry, jax.tree.map(lambda x: x[i], micro))
            loss_sum, g_sum = carry
        else:
            (loss_sum, g_sum), _ = jax.lax.scan(body, (0.0, g0), micro)
        inv = 1.0 / run.accum_steps
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        step = opt_state["step"]
        lr = cosine_warmup(step, base_lr=run.lr, warmup_steps=run.warmup_steps,
                           total_steps=run.total_steps)
        params, opt_state, gnorm = opt.step(
            params, grads, opt_state, lr,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return init_state, train_step


def make_eval_step(model: ModelFns, cfg: ModelConfig,
                   loss_fn: Optional[Callable] = None):
    loss_fn = loss_fn or lm_loss_fn(model, cfg)

    def eval_step(params, batch):
        return loss_fn(params, batch)
    return eval_step
