"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B family
[hf:moonshotai/Moonlight-16B-A3B]. 48L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=163840, MoE 64 experts top-6 (+2 shared, 1 leading dense
layer with d_ff=11264, per the public HF config)."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="transformer",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=11264, vocab=163840, head_dim=128,
        rope_theta=50000.0, max_seq=8192,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2,
                      n_dense_layers=1, dense_d_ff=11264),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-reduced", family="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab=512, head_dim=16, max_seq=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                      n_dense_layers=1, dense_d_ff=96),
    )
