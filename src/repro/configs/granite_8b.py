"""granite-8b [dense] — arXiv:2405.04324 (Granite Code 8B). llama-arch:
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152, tied
embeddings."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="transformer",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=49152, head_dim=128,
        rope_theta=10000.0, max_seq=8192, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-reduced", family="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, tie_embeddings=True, max_seq=256,
    )
