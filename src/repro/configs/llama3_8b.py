"""llama3-8b [dense] — arXiv:2407.21783. 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="transformer",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, head_dim=128,
        rope_theta=500000.0, max_seq=131072,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-reduced", family="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, max_seq=256,
    )
