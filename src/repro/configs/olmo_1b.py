"""olmo-1b [dense] — arXiv:2402.00838. 16L d_model=2048 16H (kv=16)
d_ff=8192 vocab=50304, non-parametric LayerNorm, tied embeddings."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="transformer",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304, norm="nonparam_ln",
        rope_theta=10000.0, max_seq=4096, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-reduced", family="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab=512, norm="nonparam_ln", tie_embeddings=True, max_seq=256,
    )
