"""zamba2-2.7b [hybrid] — arXiv:2411.15242. 54 Mamba2 layers d_model=2560
(ssm_state=64, expand=2, head_dim=64) with a SHARED attention block (32H
MHA kv=32, d_ff=10240) applied every 6 SSM layers. Sub-quadratic family:
long_500k decode applies (O(1) SSM state + periodic shared-attn KV)."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="zamba2",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, head_dim=80,
        rope_theta=10000.0, max_seq=1048576, attn_every=6,
        ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                      head_dim=64, chunk=256),
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-reduced", family="zamba2",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, head_dim=16, max_seq=1024, attn_every=2,
        ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2,
                      head_dim=16, chunk=16),
        sub_quadratic=True,
    )
