"""llava-next-mistral-7b [vlm] — hf:llava-hf/llava-v1.6-mistral-7b-hf.
Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000. The anyres vision tower is a STUB: input_specs supplies
(B, 576, 1024) CLIP-ViT-L/14 patch embeddings; a 2-layer MLP projector
maps them to d_model and they are prepended to the text tokens."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="llava",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        rope_theta=1000000.0, max_seq=32768,
        n_frontend_tokens=576, frontend_dim=1024,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-reduced", family="llava",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, max_seq=256,
        n_frontend_tokens=16, frontend_dim=32,
        conv_frontend=True, patch_size=4,      # (16, 16, 3) -> 4x4 patches
    )
