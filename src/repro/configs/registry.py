"""Architecture registry: --arch <id> -> ModelConfig (full or reduced),
plus per-cell (arch x shape) applicability used by the dry-run and the
roofline table."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.core.cim_linear import CIMConfig
from .base import SHAPES, ModelConfig, Shape

ARCHS: Dict[str, str] = {
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "llama3-8b": "repro.configs.llama3_8b",
    "granite-8b": "repro.configs.granite_8b",
    "olmo-1b": "repro.configs.olmo_1b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "whisper-small": "repro.configs.whisper_small",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}


def get_config(arch: str, *, reduced: bool = False,
               cim: CIMConfig | None = None) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    cfg = mod.reduced() if reduced else mod.config()
    if cim is not None:
        cfg = cfg.replace(cim=cim)
    return cfg


def cell_status(arch: str, shape_name: str) -> Tuple[bool, str]:
    """(runnable, reason). Skips: long_500k only for sub-quadratic
    families; whisper (enc-dec, 448/1500-position model) skips
    long_500k."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k":
        if not cfg.sub_quadratic:
            return False, "skip: quadratic softmax attention at 524288"
    return True, "ok"


def all_cells() -> List[Tuple[str, str, bool, str]]:
    out = []
    for arch in ARCHS:
        for sname in SHAPES:
            ok, why = cell_status(arch, sname)
            out.append((arch, sname, ok, why))
    return out
