"""xlstm-1.3b [ssm] — arXiv:2405.04517. 48 blocks d_model=2048, 4 heads,
7:1 mLSTM:sLSTM ratio, vocab=50304. Sub-quadratic: O(1) recurrent state,
so long_500k decode applies."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="xlstm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, rope_theta=0.0, max_seq=1048576,
        ssm=SSMConfig(kind="xlstm", chunk=256, slstm_every=8,
                      n_slstm_heads=4),
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-reduced", family="xlstm",
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0,
        vocab=512, rope_theta=0.0, max_seq=1024,
        ssm=SSMConfig(kind="xlstm", chunk=16, slstm_every=3,
                      n_slstm_heads=2),
        sub_quadratic=True,
    )
