"""deepseek-v3-671b [moe] — arXiv:2412.19437. 61L d_model=7168 128H MLA,
expert d_ff=2048 vocab=129280, MoE 256 experts top-8 + 1 shared, 3 leading
dense layers (d_ff=18432). MTP head omitted (next-token head only)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="transformer",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab=129280, head_dim=128,
        rope_theta=10000.0, max_seq=131072,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                      n_dense_layers=3, dense_d_ff=18432,
                      capacity_factor=1.25),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-reduced", family="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, head_dim=16, max_seq=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                      n_dense_layers=1, dense_d_ff=128),
    )
