"""qwen3-0.6b [dense] — hf:Qwen/Qwen3-0.6B family. 28L d_model=1024 16H
(GQA kv=8, head_dim=128) d_ff=3072 vocab=151936, qk-norm, tied
embeddings."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="transformer",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1000000.0, max_seq=40960,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-reduced", family="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, head_dim=16, qk_norm=True, tie_embeddings=True,
        max_seq=256,
    )
