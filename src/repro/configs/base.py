"""Model / shape / run configuration schema.

Every assigned architecture is a ``ModelConfig`` instance in its own file
under ``repro/configs/``; shapes (seq_len x global_batch x step kind) come
from the shared SHAPES registry. ``reduced()`` derives the smoke-test
variant of any config (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.cim_linear import CIMConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    n_shared: int = 0            # always-on shared experts
    capacity_factor: float = 1.25
    n_dense_layers: int = 0      # leading dense-FFN layers (deepseek: 3)
    dense_d_ff: int = 0
    router_scale: bool = True    # normalize top-k gate weights


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"         # mamba2 | xlstm
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256             # SSD / chunkwise-mLSTM chunk length
    slstm_every: int = 8         # xlstm: every Nth block is sLSTM
    n_slstm_heads: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # transformer | xlstm | zamba2 | whisper | llava | resnet
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"          # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    max_seq: int = 131072
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0          # zamba2: shared attn block every N ssm blocks
    enc_layers: int = 0          # whisper encoder layers
    n_frontend_tokens: int = 0   # vlm/audio stub tokens (576 patches / 1500 frames)
    frontend_dim: int = 0        # stub embedding dim (defaults to d_model)
    conv_frontend: bool = False  # real conv frontend (CIM conv kernel) vs stub
    patch_size: int = 0          # llava conv frontend: square patch edge
    cim: CIMConfig = dataclasses.field(default_factory=CIMConfig)
    cim_lm_head: bool = False    # also CIM-quantize the LM head
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 2048       # KV-chunked (flash-style) attention; 0=off
    flash_decode: bool = False   # shard_map seq-parallel decode attention (opt-in; §Perf)
    kv_cache_dtype: str = "bf16" # bf16 | int8 (per-(token,head) scales)
    moe_impl: str = "jit"        # jit (auto-SPMD baseline) | auto (EP shard_map; §Perf)
    sub_quadratic: bool = False  # supports long_500k decode

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving runtime knobs (distribution + optimization)."""
    microbatch: int = 0          # per-device microbatch (0 = auto/no accum)
    accum_steps: int = 1         # gradient accumulation steps
    accum_unroll: bool = False   # unroll the accum loop (HLO accounting)
    fsdp: bool = False           # shard params/opt over the data axis too
    optimizer: str = "adamw"     # adamw | adafactor | sgdm
    opt_state_dtype: str = "float32"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compress: bool = False  # int8 reduce-scatter/all-gather w/ error fb
    label_smoothing: float = 0.0
    seed: int = 0
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 200
    async_checkpoint: bool = True
