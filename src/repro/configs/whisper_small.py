"""whisper-small [audio] — arXiv:2212.04356. Enc-dec transformer backbone:
12 encoder + 12 decoder layers, d_model=768 12H d_ff=3072 vocab=51865,
LayerNorm + GELU + learned positions. The full config keeps the conv/
log-mel frontend as a STUB (input_specs supplies (B, 1500, 768) frame
embeddings); ``reduced()`` enables the real two-conv stem
(``conv_frontend``) on raw (B, 48, 16) log-mel frames so the CIM conv
deploy kernel is exercised by the zoo parity matrix.

NOTE: the released model caps decoder positions at 448 and encoder frames
at 1500; prefill_32k/decode_32k are lowered structurally (valid compute
graph, beyond the trained positions). long_500k is skipped (quadratic)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="whisper",
        n_layers=12, enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865, norm="layernorm", act="gelu",
        rope_theta=0.0, max_seq=65536,
        n_frontend_tokens=1500,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-reduced", family="whisper",
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, norm="layernorm", act="gelu",
        rope_theta=0.0, max_seq=256, n_frontend_tokens=24,
        conv_frontend=True, frontend_dim=16,   # 16 mel bins, 48 raw frames
    )
