from .optimizer import (adafactor_init, adamw_init, make_optimizer, sgdm_init)
from .schedule import cosine_warmup

__all__ = ["adafactor_init", "adamw_init", "cosine_warmup", "make_optimizer",
           "sgdm_init"]
