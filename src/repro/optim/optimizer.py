"""Optimizers (no optax on this box): AdamW with configurable state dtype
(bf16 states halve optimizer HBM — how deepseek-v3-671b train fits 512
chips), Adafactor (factored second moment: O(n+m) instead of O(nm) state),
and SGD-momentum. All are pytree->pytree pure functions:

  state = <name>_init(params, dtype)
  params, state = step(params, grads, state, lr, ...)

Global-norm clipping and decoupled weight decay are applied inside step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.asarray(0.0)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_step(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
               weight_decay=0.1, grad_clip=1.0):
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    t = state["step"] + 1
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step_ = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_ + weight_decay * pf)
        return pf.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": t}, gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moments for >=2D params)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor_init(params, state_dtype=jnp.float32):
    def init_leaf(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], state_dtype),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], state_dtype)}
        return {"v": jnp.zeros(p.shape, state_dtype)}
    return {"v": jax.tree.map(init_leaf, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_step(params, grads, state, lr, *, decay=0.99, eps=1e-30,
                   weight_decay=0.0, grad_clip=1.0, clip_threshold=1.0):
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    t = state["step"] + 1

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p.shape):
            vr = decay * v["vr"].astype(jnp.float32) + (1 - decay) * g2.mean(-1)
            vc = decay * v["vc"].astype(jnp.float32) + (1 - decay) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1, keepdims=True)[..., None], eps))
            u = gf / jnp.sqrt(denom + eps)
            new_v = {"vr": vr.astype(v["vr"].dtype), "vc": vc.astype(v["vc"].dtype)}
        else:
            vv = decay * v["v"].astype(jnp.float32) + (1 - decay) * g2
            u = gf / jnp.sqrt(vv + eps)
            new_v = {"v": vv.astype(v["v"].dtype)}
        # update clipping (adafactor RMS rule)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        pf = p.astype(jnp.float32)
        pf = pf - lr * u - lr * weight_decay * pf
        return pf.astype(p.dtype), new_v

    is_state_leaf = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, params, grads, state["v"], is_leaf=None)
    # jax.tree.map zips params/grads with the state subtree; unpack tuples
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"v": new_v, "step": t}, gnorm


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgdm_init(params, state_dtype=jnp.float32):
    return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype),
                                params),
            "step": jnp.zeros((), jnp.int32)}


def sgdm_step(params, grads, state, lr, *, momentum=0.9, weight_decay=0.0,
              grad_clip=1.0):
    grads, gnorm = clip_by_global_norm(grads, grad_clip)

    def upd(p, g, m):
        gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m_new = momentum * m.astype(jnp.float32) + gf
        return ((p.astype(jnp.float32) - lr * m_new).astype(p.dtype),
                m_new.astype(m.dtype))

    out = jax.tree.map(upd, params, grads, state["mom"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mom": new_m, "step": state["step"] + 1}, gnorm


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    step: Callable               # (params, grads, state, lr, **kw)


def make_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return Optimizer(adamw_init, adamw_step)
    if name == "adafactor":
        return Optimizer(adafactor_init, adafactor_step)
    if name == "sgdm":
        return Optimizer(sgdm_init, sgdm_step)
    raise ValueError(f"unknown optimizer {name!r}")
