"""LR schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, base_lr: float, warmup_steps: int,
                  total_steps: int, min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, warmup_steps))
    prog = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                    0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
