"""Evaluation harnesses: scenario sweeps over the deploy kernels.

``repro.eval.robustness`` is the Monte-Carlo cell-variation subsystem
(paper §IV-E / Fig. 10): sigma-grid sweeps of accuracy and partial-sum
error on the fused Pallas deploy path, with per-layer error attribution.

``repro.eval.recalibrate`` is the in-service recalibration subsystem
(DESIGN.md §11): probe-based re-fitting of the column-wise scale factors
against an observed (drifted) chip, shipped as a versioned ``ScaleDelta``
applied to a loaded ``DeployArtifact`` without touching the digit planes.
"""
from .recalibrate import (ScaleDelta, apply_scale_delta,
                          apply_scale_delta_params, fit_scale_delta,
                          node_gain)
from .robustness import (LayerAttribution, RobustnessSweep,
                         monte_carlo_linear_error, monte_carlo_resnet,
                         per_layer_attribution)

__all__ = [
    "LayerAttribution", "RobustnessSweep", "ScaleDelta",
    "apply_scale_delta", "apply_scale_delta_params", "fit_scale_delta",
    "monte_carlo_linear_error", "monte_carlo_resnet", "node_gain",
    "per_layer_attribution",
]
