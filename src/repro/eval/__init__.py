"""Evaluation harnesses: scenario sweeps over the deploy kernels.

``repro.eval.robustness`` is the Monte-Carlo cell-variation subsystem
(paper §IV-E / Fig. 10): sigma-grid sweeps of accuracy and partial-sum
error on the fused Pallas deploy path, with per-layer error attribution.
"""
from .robustness import (LayerAttribution, RobustnessSweep,
                         monte_carlo_linear_error, monte_carlo_resnet,
                         per_layer_attribution)

__all__ = [
    "LayerAttribution", "RobustnessSweep", "monte_carlo_linear_error",
    "monte_carlo_resnet", "per_layer_attribution",
]
