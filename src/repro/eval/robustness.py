"""Monte-Carlo cell-variation robustness harness (paper §IV-E, Fig. 10).

Runs N-sample sigma-grid sweeps of accuracy and output error **on the
fused Pallas deploy kernels** — the configuration that would actually
ship — not the n_split-replicated emulate fallback. Three design points
keep the sweep at kernel speed:

* the packed int digit planes are built ONCE; each Monte-Carlo sample is
  a lazy log-normal perturbation keyed by ``fold_in(key, sample)``
  (``core.variation.perturb_packed`` semantics — no re-packing);
* ``sigma`` is fed as a *traced* scalar, so one jitted evaluation step
  serves the entire sigma grid with zero recompiles;
* samples share device realizations across sigma levels (common random
  numbers): sample i draws the same theta field at every sigma, so the
  sigma-monotonicity of the error curve is not drowned by sampling noise.

Per-layer attribution re-evaluates each CIM conv in isolation — clean
input taps from ``resnet.forward(return_taps=True)``, noise keyed by the
same ``resnet.variation_keys`` split the end-to-end forward consumes — so
"which columns' scale factors absorb the drift" is answered with exactly
the noise the full network saw.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import conv2d, linear
from repro.core.cim_linear import CIMConfig
from repro.core.variation import DriftSchedule
from repro.models import resnet


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RobustnessSweep:
    """Monte-Carlo sweep result: axis 0 indexes sigmas, axis 1 samples."""
    sigmas: Tuple[float, ...]
    n_samples: int
    acc: np.ndarray            # (n_sigma, n_samples) top-1 accuracy
    logit_err: np.ndarray      # (n_sigma, n_samples) rel logits error
    acc_clean: float           # no-noise deploy accuracy

    @property
    def acc_mean(self) -> np.ndarray:
        return self.acc.mean(axis=1)

    @property
    def acc_std(self) -> np.ndarray:
        return self.acc.std(axis=1)

    @property
    def logit_err_mean(self) -> np.ndarray:
        return self.logit_err.mean(axis=1)


@dataclasses.dataclass
class LayerAttribution:
    """Layer-local error under the end-to-end noise realization."""
    name: str
    rel_err: float             # ||y_noisy - y_clean|| / ||y_clean||
    col_err: np.ndarray        # (C_out,) per-output-column relative error
    worst_col: int
    worst_col_err: float
    median_col_err: float


# ---------------------------------------------------------------------------
# linear-layer sweep (psum/output error; the statistical-test workhorse)
# ---------------------------------------------------------------------------

def monte_carlo_linear_error(
    packed: Dict[str, jnp.ndarray],
    cfg: CIMConfig,
    x: jnp.ndarray,
    *,
    key: jax.Array,
    sigmas: Sequence[float],
    n_samples: int = 8,
) -> np.ndarray:
    """Relative deploy-output error per (sigma, sample), vs the clean
    deploy output. ``packed`` comes from ``repro.api.pack_linear``; the evaluation
    runs the deploy path of ``repro.api.linear`` (Pallas kernel when
    ``cfg.use_kernel``). Returns (n_sigma, n_samples) float64.

    A cfg already on a packed hardware-style backend (deploy/ref/
    adc_free/binary — DESIGN.md §13) is evaluated on THAT backend, so
    one harness sweeps every style's variation robustness; non-packed
    cfgs (emulate) pin to deploy as before."""
    from repro.api import _packed_config
    dcfg = _packed_config(cfg)

    @jax.jit
    def _eval(k, sigma):
        return linear(x, packed, dcfg, variation_key=k,
                          variation_std=sigma, compute_dtype=jnp.float32)

    y_clean = linear(x, packed, dcfg, compute_dtype=jnp.float32)
    denom = float(jnp.linalg.norm(y_clean)) + 1e-12
    out = np.zeros((len(sigmas), n_samples))
    for i in range(n_samples):
        k_i = jax.random.fold_in(key, i)
        for si, sigma in enumerate(sigmas):
            if sigma <= 0.0:
                continue
            y = _eval(k_i, jnp.float32(sigma))
            out[si, i] = float(jnp.linalg.norm(y - y_clean)) / denom
    return out


# ---------------------------------------------------------------------------
# full-model Monte-Carlo accuracy sweep
# ---------------------------------------------------------------------------

def monte_carlo_resnet(
    params: Dict,
    state: Dict,
    cfg: "resnet.ResNetConfig",
    x,
    y,
    *,
    key: jax.Array,
    sigmas: Sequence[float] = (0.0, 0.1, 0.2, 0.3, 0.4),
    n_samples: int = 4,
    batch: int = 128,
    drift_schedule: Optional[DriftSchedule] = None,
    drift_ts: Sequence[int] = (0, 64, 128, 256, 512),
) -> RobustnessSweep:
    """Sigma-grid Monte-Carlo accuracy/logit-error sweep of a (packed,
    deploy-mode) ResNet. ``params`` is the ``repro.api.pack_model`` tree and
    ``cfg.cim.mode`` should be "deploy" so the sweep exercises the fused
    Pallas kernels; the same call also accepts emulate params/cfg for
    cross-path comparisons.

    With ``drift_schedule`` the sweep runs over the time-indexed drift
    process instead of the static sigma grid: the grid axis becomes
    ``drift_ts`` (request counts; reported in ``RobustnessSweep.sigmas``)
    and each evaluation perturbs with ``drift_schedule.at(t)``. The
    traced-scalar trick carries over — ``t`` is the DriftState's only
    leaf, so one jitted step serves the whole time grid — and so does
    CRN: sample ``i``'s persistent cell/column fields are shared across
    every ``t`` by construction (they are keyed independently of ``t``),
    so the time-monotonicity of the drift curve is sampling-noise-free."""

    @jax.jit
    def _logits(xb, k, sigma):
        lg, _ = resnet.forward(params, state, xb, cfg, train=False,
                               variation_key=k, variation_std=sigma)
        return lg

    @jax.jit
    def _logits_clean(xb):
        lg, _ = resnet.forward(params, state, xb, cfg, train=False)
        return lg

    n = len(x)
    xb_list = [jnp.asarray(x[i:i + batch]) for i in range(0, n, batch)]
    yb_list = [np.asarray(y[i:i + batch]) for i in range(0, n, batch)]
    clean = [_logits_clean(xb) for xb in xb_list]
    acc_clean = sum(int((np.asarray(jnp.argmax(lg, -1)) == yb).sum())
                    for lg, yb in zip(clean, yb_list)) / n
    clean_sq = sum(float(jnp.sum(lg.astype(jnp.float32) ** 2))
                   for lg in clean)

    if drift_schedule is not None:
        grid = tuple(int(t) for t in drift_ts)
        skip_clean = drift_schedule.is_static_zero

        def _std(g):
            return drift_schedule.at(jnp.int32(g))
    else:
        grid = tuple(float(s) for s in sigmas)

    acc = np.zeros((len(grid), n_samples))
    err = np.zeros((len(grid), n_samples))
    for i in range(n_samples):
        k_i = jax.random.fold_in(key, i)
        for si, g in enumerate(grid):
            if (drift_schedule is None and g <= 0.0) or (
                    drift_schedule is not None and skip_clean):
                acc[si, i] = acc_clean
                continue
            std = _std(g) if drift_schedule is not None else jnp.float32(g)
            correct, diff_sq = 0, 0.0
            for xb, yb, lg_c in zip(xb_list, yb_list, clean):
                lg = _logits(xb, k_i, std)
                correct += int((np.asarray(jnp.argmax(lg, -1)) == yb).sum())
                diff_sq += float(jnp.sum(
                    (lg.astype(jnp.float32) - lg_c.astype(jnp.float32)) ** 2))
            acc[si, i] = correct / n
            err[si, i] = np.sqrt(diff_sq) / (np.sqrt(clean_sq) + 1e-12)
    return RobustnessSweep(sigmas=tuple(float(g) for g in grid),
                           n_samples=n_samples, acc=acc, logit_err=err,
                           acc_clean=acc_clean)


# ---------------------------------------------------------------------------
# per-layer error attribution
# ---------------------------------------------------------------------------

def per_layer_attribution(
    params: Dict,
    state: Dict,
    cfg: "resnet.ResNetConfig",
    x: jnp.ndarray,
    *,
    key: jax.Array,
    sigma: float,
    sample: int = 0,
) -> Tuple[LayerAttribution, ...]:
    """Layer-local variation error under the SAME noise the end-to-end
    forward draws for Monte-Carlo sample ``sample``.

    Each CIM conv is re-evaluated in isolation on its clean input tap,
    with and without its per-layer noise key, so a layer's entry reflects
    the drift its own arrays inject — not error inherited from upstream.
    The per-column breakdown shows which output columns' scale factors
    absorb the drift (small ``col_err``) and which let it through."""
    _, _, taps = resnet.forward(params, state, x, cfg, train=False,
                                return_taps=True)
    k_sample = jax.random.fold_in(key, sample)
    vkeys = resnet.variation_keys(k_sample, cfg)
    out = []
    for lname, stride in resnet.conv_layer_names(cfg):
        blk, conv = lname.split(".")
        node = params[blk][conv]
        tap = taps[lname]
        y_clean = conv2d(tap, node, cfg.cim, stride=stride,
                             compute_dtype=jnp.float32)
        y_noisy = conv2d(tap, node, cfg.cim, stride=stride,
                             variation_key=vkeys[lname],
                             variation_std=jnp.float32(sigma),
                             compute_dtype=jnp.float32)
        diff = (y_noisy - y_clean).astype(jnp.float32)
        denom = jnp.linalg.norm(y_clean) + 1e-12
        rel = float(jnp.linalg.norm(diff) / denom)
        col_norm = jnp.sqrt(jnp.sum(y_clean.astype(jnp.float32) ** 2,
                                    axis=(0, 1, 2))) + 1e-12
        col_err = np.asarray(
            jnp.sqrt(jnp.sum(diff ** 2, axis=(0, 1, 2))) / col_norm)
        worst = int(np.argmax(col_err))
        out.append(LayerAttribution(
            name=lname, rel_err=rel, col_err=col_err, worst_col=worst,
            worst_col_err=float(col_err[worst]),
            median_col_err=float(np.median(col_err))))
    return tuple(out)
