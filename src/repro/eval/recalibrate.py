"""In-service column-scale recalibration (DESIGN.md §11).

The paper's independent column-wise scale factors absorb cell variation
at QAT time (§IV-E, Eq. 5); this module re-fits them *in the field*
against conductance drift, without touching the packed digit planes —
the serving analogue of on-chip finetuning restricted to the cheapest
parameter set the architecture exposes.

The fit treats every physical array column as a one-parameter channel:
probe row-codes drive both the pristine planes and the drifted planes
through the same column MAC, and the least-squares gain

    g[s, t, n] = sum_p P_ref * P_obs / sum_p P_ref^2

maps clean partial sums to drifted ones per (split, k_tile, column).
Column-gain drift (the component a bitline/ADC ages coherently) is
recovered *exactly* — the psum is linear in the column's cells — while
incoherent per-cell drift is absorbed in the least-squares sense.

A fitted ``ScaleDelta`` corrects the serving arithmetic in two places:
``s_p' = s_p * g`` re-centers the ADC range on the drifted partial sums
(reduced to the psum-scale granularity when coarser than COLUMN), and
``deq_scale = 1/g`` (a new, optional packed-node leaf the deploy
forwards consume) divides the gain back out of the dequantized output.
Net effect under pure column drift: clean outputs, to float rounding.

Deltas are **absolute**: fitted against the pristine artifact and
applied to the pristine artifact. They version independently of the
artifact layout (``SCALE_DELTA_VERSION``) and are persisted through the
artifact's own leaf store, so the round trip is bit-exact.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.artifact import (ARTIFACT_LAYOUT_VERSION, SCALE_DELTA_VERSION,
                                _DELTA_WRITERS, _LAYOUT_WRITERS,
                                ArtifactVersionError, DeployArtifact)
from repro.checkpoint import ckpt as _ckpt
from repro.core.variation import path_fold_key

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class ScaleDelta:
    """A versioned column-gain correction for one packed model tree.

    ``gains`` maps '/'-joined packed-node paths (the same paths
    ``meta["col_shard"]`` records) to the fitted per-column psum gain,
    shaped like the node's full psum scale — (S, kt, N), with a leading
    layer axis for stacked nodes. ``layout_version`` pins the artifact
    layout the delta was fitted against; applying it to an artifact of a
    different layout raises ``ArtifactVersionError``.
    """

    gains: Dict[str, np.ndarray]
    delta_version: int = SCALE_DELTA_VERSION
    layout_version: int = ARTIFACT_LAYOUT_VERSION
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- persistence (artifact leaf store + header, like DeployArtifact) ----

    def save(self, path: str) -> str:
        os.makedirs(path, exist_ok=True)
        stale = os.path.join(path, "delta.json")
        if os.path.exists(stale):
            os.remove(stale)
        _ckpt.save(path, 0, {"gains": dict(self.gains)})
        head = {
            "format": "repro.eval.ScaleDelta",
            "delta_version": self.delta_version,
            "layout_version": self.layout_version,
            "meta": self.meta,
        }
        jpath = os.path.join(path, "delta.json")
        tmp = jpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(head, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, jpath)
        return path

    @classmethod
    def load(cls, path: str) -> "ScaleDelta":
        jpath = os.path.join(path, "delta.json")
        if not os.path.exists(jpath):
            raise FileNotFoundError(f"{path} is not a ScaleDelta "
                                    "(no delta.json)")
        with open(jpath) as f:
            head = json.load(f)
        dv = head.get("delta_version")
        if dv is None or dv > SCALE_DELTA_VERSION:
            raise ArtifactVersionError(
                f"ScaleDelta at {path}", "delta_version", dv,
                SCALE_DELTA_VERSION, writers=_DELTA_WRITERS)
        tree = _ckpt.restore_tree(path, step=0)
        gains = {k: np.asarray(v) for k, v in tree["gains"].items()}
        return cls(gains=gains, delta_version=dv,
                   layout_version=head["layout_version"],
                   meta=dict(head.get("meta", {})))


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------

def _row_flat(planes: jnp.ndarray) -> jnp.ndarray:
    """Packed planes -> (lead?, S, kt, R, N) float32, rows flattened
    row-major (identical order on the 4-D linear and 6-D conv layouts).
    Nibble-packed (uint8) planes decode to their logical layout first, so
    a pristine v4 reference fits against float observed planes (drifted
    planes are always logical — ``perturb_packed`` unpacks)."""
    if jnp.dtype(planes.dtype) == jnp.dtype(jnp.uint8):
        from repro.core.nibble import unpack_nibbles
        planes = unpack_nibbles(planes)
    lead = 1 if planes.ndim in (5, 7) else 0
    shape = planes.shape
    rows = int(np.prod(shape[lead + 2:-1]))
    flat = (shape[:lead + 2] + (rows, shape[-1]))
    return planes.astype(jnp.float32).reshape(flat)


def _gain_4d(d_ref, d_obs, codes):
    """Least-squares per-column gain from probe codes (P, kt, R) driving
    (S, kt, R, N) pristine and observed planes -> (S, kt, N)."""
    p_ref = jnp.einsum("ptr,strn->pstn", codes, d_ref)
    p_obs = jnp.einsum("ptr,strn->pstn", codes, d_obs)
    num = jnp.sum(p_ref * p_obs, axis=0)
    den = jnp.sum(p_ref * p_ref, axis=0)
    # all-zero columns (padding, dead filters) carry no signal: gain 1
    return jnp.where(den > _EPS, num / jnp.maximum(den, _EPS), 1.0)


def node_gain(ref_planes, obs_planes, *, key: Optional[jax.Array] = None,
              probes: int = 32, codes=None) -> jnp.ndarray:
    """Fit one packed node's per-column gain. ``codes`` (P, kt, R) are
    the probe rows — activation codes replayed from recent requests, or
    (default) Rademacher +-1 probes drawn from ``key``. Stacked nodes
    (leading layer axis) share the codes and vmap the fit."""
    d_ref, d_obs = _row_flat(ref_planes), _row_flat(obs_planes)
    kt, rows = d_ref.shape[-3], d_ref.shape[-2]
    if codes is None:
        if key is None:
            raise ValueError("node_gain needs `codes` or a probe `key`")
        codes = jax.random.rademacher(key, (probes, kt, rows), jnp.float32)
    codes = jnp.asarray(codes, jnp.float32)
    if d_ref.ndim == 5:
        return jax.vmap(_gain_4d, in_axes=(0, 0, None))(d_ref, d_obs, codes)
    return _gain_4d(d_ref, d_obs, codes)


def fit_scale_delta(reference, observed, *, key: Optional[jax.Array] = None,
                    probes: int = 32,
                    codes: Optional[Mapping[str, Any]] = None,
                    meta: Optional[Dict[str, Any]] = None) -> ScaleDelta:
    """Fit a ``ScaleDelta`` mapping ``reference`` (pristine packed tree,
    or a ``DeployArtifact``) to ``observed`` (the same tree with drifted
    planes — e.g. ``core.variation.drift_tree`` output, or planes read
    back from a real chip).

    ``codes`` optionally supplies per-node replay probe codes
    ({'/'-joined path: (P, kt, R)}); nodes without an entry fall back to
    Rademacher probes keyed per node by ``path_fold_key(key, path)``.
    """
    layout = ARTIFACT_LAYOUT_VERSION
    if isinstance(reference, DeployArtifact):
        layout = reference.layout_version
        reference = reference.params
    if isinstance(observed, DeployArtifact):
        observed = observed.params
    gains: Dict[str, np.ndarray] = {}

    def walk(ref, obs, path):
        if isinstance(ref, dict):
            if "w_digits" in ref:
                name = "/".join(path)
                node_codes = codes.get(name) if codes else None
                k = None if key is None else path_fold_key(key, path)
                g = node_gain(ref["w_digits"], obs["w_digits"], key=k,
                              probes=probes, codes=node_codes)
                gains[name] = np.asarray(g)
                return
            for k2 in ref:
                walk(ref[k2], obs[k2], path + (k2,))
        elif isinstance(ref, (list, tuple)):
            for i, v in enumerate(ref):
                walk(v, obs[i], path + (str(i),))
    walk(reference, observed, ())
    return ScaleDelta(gains=gains, layout_version=layout,
                      meta=dict(meta or {}))


# ---------------------------------------------------------------------------
# application
# ---------------------------------------------------------------------------

def _reduce_to(g: jnp.ndarray, shape) -> jnp.ndarray:
    """Reduce a full (…, S, kt, N) gain to a coarser psum-scale shape
    (ARRAY/LAYER granularities) by averaging the broadcast group. The
    range re-centering becomes approximate there; the exact correction
    still lands in ``deq_scale``, which is always full-column."""
    if tuple(g.shape) == tuple(shape):
        return g
    for ax in range(-1, -len(shape) - 1, -1):
        if g.shape[ax] != shape[ax]:
            g = g.mean(axis=ax, keepdims=True)
    return jnp.broadcast_to(g, shape)


def _placed_like(arr: jnp.ndarray, ref) -> jnp.ndarray:
    """Place ``arr`` carrying ``ref``'s *column* sharding (both end in
    the column axis, whatever their ranks) — on a column-sharded
    artifact every device receives, and later multiplies, only its own
    column slice of the gain; ragged/replicated nodes replicate."""
    sh = getattr(ref, "sharding", None)
    spec = getattr(sh, "spec", None)
    if spec is None or getattr(sh, "mesh", None) is None:
        return jnp.asarray(arr)
    try:
        from jax.sharding import NamedSharding, PartitionSpec as P
        col = spec[-1] if len(spec) else None
        new_spec = P(*([None] * (arr.ndim - 1) + [col]))
        return jax.device_put(arr, NamedSharding(sh.mesh, new_spec))
    except (ValueError, TypeError):
        return jnp.asarray(arr)


def apply_scale_delta_params(params, delta: ScaleDelta):
    """Apply a delta to a pristine packed tree: per fitted node,
    ``s_p *= reduce(g)`` and ``deq_scale = 1/g``; digit planes and every
    other leaf pass through untouched (same objects — no copies). Nodes
    the delta does not name are left alone."""
    def walk(node, path):
        if isinstance(node, dict):
            name = "/".join(path)
            if "w_digits" in node and name in delta.gains:
                g = jnp.asarray(delta.gains[name], jnp.float32)
                out = dict(node)
                s_p = node["s_p"]
                g_sp = _placed_like(np.asarray(_reduce_to(g, s_p.shape)), s_p)
                out["s_p"] = (s_p.astype(jnp.float32) * g_sp
                              ).astype(s_p.dtype)
                out["deq_scale"] = _placed_like(
                    np.asarray(1.0 / g, np.float32), node["w_digits"])
                return out
            if "w_digits" in node:
                return node
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, path + (str(i),)) for i, v in enumerate(node)]
        return node
    return walk(params, ())


def apply_scale_delta(artifact: DeployArtifact,
                      delta: ScaleDelta) -> DeployArtifact:
    """Apply a ``ScaleDelta`` to a loaded (possibly column-sharded)
    ``DeployArtifact``. Deltas are absolute w.r.t. the pristine artifact
    they were fitted from: re-applying on top of an already-recalibrated
    artifact would compound gains, so that is refused. Version pinning:
    a delta fitted against a different artifact layout, or written by a
    newer delta format, raises ``ArtifactVersionError``."""
    if delta.delta_version > SCALE_DELTA_VERSION:
        raise ArtifactVersionError(
            "ScaleDelta", "delta_version", delta.delta_version,
            SCALE_DELTA_VERSION, writers=_DELTA_WRITERS)
    if delta.layout_version != artifact.layout_version:
        raise ArtifactVersionError(
            "ScaleDelta (stale)", "layout_version", delta.layout_version,
            artifact.layout_version, writers=_LAYOUT_WRITERS,
            relation="==",
            detail="The delta was fitted against a different artifact "
                   "layout; re-fit it against this artifact.")
    if "delta_version" in artifact.meta:
        raise ValueError(
            "apply_scale_delta: artifact already carries a ScaleDelta "
            "(meta['delta_version'] set); deltas are absolute — apply to "
            "the pristine artifact instead of compounding.")
    params = apply_scale_delta_params(artifact.params, delta)
    meta = {**artifact.meta, "delta_version": delta.delta_version,
            "recal": dict(delta.meta)}
    return dataclasses.replace(artifact, params=params, meta=meta)
