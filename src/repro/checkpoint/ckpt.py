"""Fault-tolerant checkpointing.

Atomicity: every leaf is written to ``<dir>/step_N.tmp/`` and the whole
directory is renamed to ``step_N/`` only after the manifest is fsynced —
a crash mid-save never corrupts the latest valid checkpoint. Restore scans
for the newest complete manifest (auto-resume after node failure).

Elastic restore: pass target ``shardings`` and every leaf is device_put
onto the new mesh — a checkpoint written on 512 chips restores onto 256
(or 1) without conversion, because leaves are stored as full logical
arrays (per-host sharded writes would use process-local shards + a fan-in
merge on real multi-host fleets; see runtime/fault_tolerance.py notes).

Async: ``CheckpointManager(async_save=True)`` snapshots to host memory on
the training thread (cheap) and writes on a background thread so the
accelerator never waits on the filesystem.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import queue
from typing import Any, Dict, Optional

import jax
import numpy as np


_LIST_KEY = re.compile(r"^__\d+$")


def _flatten(tree, path=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            if isinstance(k, str) and _LIST_KEY.match(k):
                # '__<i>' is the reserved list encoding; a dict using it
                # would be indistinguishable from a list on restore_tree
                raise ValueError(
                    f"dict key {k!r} at {path or '<root>'} collides with "
                    "the reserved list encoding '__<index>'; rename it")
            yield from _flatten(tree[k], f"{path}/{k}" if path else str(k))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{path}/__{i}")
    else:
        yield path, tree


def _empty_containers(tree, path=""):
    """Paths of leafless containers — invisible to _flatten, but part of
    the tree structure (e.g. parameter-free norm nodes)."""
    if isinstance(tree, dict):
        if not tree:
            yield path, "dict"
        for k in sorted(tree):
            yield from _empty_containers(tree[k],
                                         f"{path}/{k}" if path else str(k))
    elif isinstance(tree, (tuple, list)):
        if not tree:
            yield path, "list"
        for i, v in enumerate(tree):
            yield from _empty_containers(v, f"{path}/__{i}")


def _unflatten_into(like, flat: Dict[str, np.ndarray], path=""):
    if isinstance(like, dict):
        return {k: _unflatten_into(like[k], flat,
                                   f"{path}/{k}" if path else str(k))
                for k in like}
    if isinstance(like, (tuple, list)):
        vals = [_unflatten_into(v, flat, f"{path}/__{i}")
                for i, v in enumerate(like)]
        return type(like)(vals)
    return flat[path]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write checkpoint atomically; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}}
    for i, (path, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        # store raw bytes (uint8) + logical dtype: np.save cannot roundtrip
        # ml_dtypes (bfloat16) natively
        np.save(os.path.join(tmp, fname),
                np.frombuffer(np.ascontiguousarray(arr).tobytes(), np.uint8))
        manifest["leaves"][path] = {"file": fname, "shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    empty = dict(_empty_containers(tree))
    if empty:
        manifest["empty"] = empty
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def _load_leaves(ckpt_dir: str, step: Optional[int]):
    """Shared restore substrate: ({manifest path: leaf}, {path: kind} of
    empty containers) with logical dtypes (bfloat16/int4 via ml_dtypes),
    newest step when unspecified."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes  # noqa: F401  (registers bfloat16/int4 with numpy)
    flat = {}
    for p, meta in manifest["leaves"].items():
        raw = np.load(os.path.join(path, meta["file"]))
        flat[p] = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
    return flat, manifest.get("empty", {})


def restore_tree(ckpt_dir: str, step: Optional[int] = None) -> Any:
    """Restore a checkpoint WITHOUT a ``like`` template: the nested
    dict/list structure is rebuilt from the manifest paths. This is what
    self-describing artifacts (``repro.api.DeployArtifact``) load through
    — the artifact on disk is the source of truth, not caller-side specs.
    Leaves come back as numpy arrays with their logical dtypes; leafless
    containers (recorded in the manifest's ``empty`` section) are
    reinstated so the structure is byte-for-byte what was saved."""
    flat, empty = _load_leaves(ckpt_dir, step)
    root: Dict[str, Any] = {}
    for p, leaf in flat.items():
        parts = p.split("/")
        if parts and parts[0] == "":
            parts = parts[1:]   # '/__0'-style paths: root is a list/tuple
        if not parts:
            return leaf         # bare-leaf root: the tree IS this leaf
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf
    for p, kind in empty.items():
        placeholder: Any = {} if kind == "dict" else []
        if p == "":
            return placeholder  # whole tree is one empty container
        parts = [q for q in p.split("/") if q != ""]
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = placeholder

    def listify(node):
        if not isinstance(node, dict):
            return node
        out = {k: listify(v) for k, v in node.items()}
        # only the exact '__0'..'__n-1' contiguous pattern is _flatten's
        # list encoding; any other '__'-prefixed keys stay a dict
        if out and all(k.startswith("__") for k in out):
            try:
                nums = sorted(int(k[2:]) for k in out)
            except ValueError:
                return out
            if nums == list(range(len(out))):
                return [out[f"__{i}"] for i in range(len(out))]
        return out

    return listify(root)


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``. ``shardings`` (matching
    pytree of jax.sharding.Sharding) reshards onto the current mesh."""
    tree = _unflatten_into(like, _load_leaves(ckpt_dir, step)[0])
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class CheckpointManager:
    """keep_n retention + optional async writes + emergency save hook."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3,
                 async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep_n = keep_n
        self.async_save = async_save
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_save:
            self._worker = threading.Thread(target=self._loop, daemon=True)
            self._worker.start()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:   # surfaced on next save()
                self._error = e

    def _gc(self):
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(s for s in (
            int(n[5:]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree: Any):
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        # snapshot to host (blocks only on device->host copy)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_save:
            self._q.put((step, host_tree))
        else:
            save(self.ckpt_dir, step, host_tree)
            self._gc()

    def wait(self):
        """Drain pending async writes (call before exit)."""
        if self._worker is not None:
            self._q.put(None)
            self._worker.join()
            self._worker = threading.Thread(target=self._loop, daemon=True)
            self._worker.start()

    def latest_step(self) -> Optional[int]:
        return latest_step(self.ckpt_dir)

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        return restore(self.ckpt_dir, like, step, shardings)
