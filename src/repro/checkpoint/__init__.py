from .ckpt import (CheckpointManager, latest_step, restore, restore_tree,
                   save)

__all__ = ["CheckpointManager", "latest_step", "restore", "restore_tree",
           "save"]
