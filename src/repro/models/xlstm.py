"""xLSTM LM (arXiv:2405.04517): mLSTM blocks (matrix-memory, chunkwise-
parallel like linear attention) at a 7:1 ratio with sLSTM blocks (scalar
memory, strictly recurrent with exponential gating). Both carry O(1) state
per layer, so long_500k decode is constant-memory — the sub-quadratic
family the assignment routes long-context cells to.

Stabilization follows the paper: log-sigmoid forget gates, exponential
input gates, running max-state m so all exponentials are <= 1.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec, stack_specs
from .layers import apply_norm, cdt, norm_specs, pdt


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    nh = cfg.n_heads
    hd = d_inner // nh
    return d_inner, nh, hd


def mlstm_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_inner, nh, hd = _mlstm_dims(cfg)
    dt = pdt(cfg)
    return {
        "ln": norm_specs(cfg),
        "up": linear_specs(d, 2 * d_inner, cim=cfg.cim, in_axis="embed",
                           out_axis="mlp", dtype=dt),
        "conv_w": ParamSpec((4, d_inner), dt, "fan_in:1.0", (None, "mlp")),
        "conv_b": ParamSpec((d_inner,), jnp.float32, "zeros", ("mlp",)),
        "wq": linear_specs(d_inner, d_inner, cim=cfg.cim, in_axis="mlp",
                           out_axis="heads", dtype=dt),
        "wk": linear_specs(d_inner, d_inner, cim=cfg.cim, in_axis="mlp",
                           out_axis="heads", dtype=dt),
        "wv": linear_specs(d_inner, d_inner, cim=cfg.cim, in_axis="mlp",
                           out_axis="heads", dtype=dt),
        "w_if": linear_specs(d_inner, 2 * nh, in_axis="mlp", out_axis=None,
                             dtype=jnp.float32),
        "out_norm": {"scale": ParamSpec((d_inner,), jnp.float32, "ones", ("mlp",))},
        "down": linear_specs(d_inner, d, cim=cfg.cim, in_axis="mlp",
                             out_axis="embed", dtype=dt),
    }


def _causal_conv1d(x, w, b, state=None):
    k = w.shape[0]
    xin = (jnp.concatenate([state, x], axis=1) if state is not None
           else jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0))))
    y = sum(xin[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(k))
    new_state = xin[:, -(k - 1):, :]
    return jax.nn.silu(y + b[None, None]), new_state


def _mlstm_chunked(q, k, v, li, lf, chunk: int, carry=None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B, L, H, hd); li, lf: (B, L, H) log input / log forget gates.
    carry: optional (C, n, m) state. Returns y (B,L,H,hd) and final carry.
    """
    b, L, H, hd = q.shape
    q = q.astype(jnp.float32) / jnp.sqrt(float(hd))
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    pad = (-L) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    shp = (b, nc, chunk, H)
    qc = q.reshape(b, nc, chunk, H, hd).swapaxes(0, 1)
    kc = k.reshape(b, nc, chunk, H, hd).swapaxes(0, 1)
    vc = v.reshape(b, nc, chunk, H, hd).swapaxes(0, 1)
    lic = li.reshape(shp).swapaxes(0, 1)
    lfc = lf.reshape(shp).swapaxes(0, 1)

    if carry is None:
        C0 = jnp.zeros((b, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, H, hd), jnp.float32)
        m0 = jnp.full((b, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = carry

    def body(state, inp):
        C, n, m = state
        qb, kb, vb, lib, lfb = inp                       # (B, Q, H, ...)
        F = jnp.cumsum(lfb, axis=1)                      # (B,Q,H) inclusive
        p = lib - F                                      # source potentials
        M = jnp.maximum(jax.lax.cummax(p, axis=1), m[:, None, :])
        # intra-chunk: S[i,j] = (q_i . k_j) * exp(p_j - M_i), j <= i
        dots = jnp.einsum("bihd,bjhd->bhij", qb, kb)
        mask = jnp.tril(jnp.ones((qb.shape[1], qb.shape[1]), bool))
        w_arg = (p.swapaxes(1, 2)[:, :, None, :]             # p_j
                 - M.swapaxes(1, 2)[:, :, :, None])          # M_i
        w_ij = jnp.exp(jnp.where(mask[None, None], w_arg, -jnp.inf))
        S = dots * w_ij
        y = jnp.einsum("bhij,bjhd->bihd", S, vb)
        # inter-chunk state contribution: weight exp(m - M_i)
        w_st = jnp.exp(m[:, None, :] - M)                    # (B,Q,H)
        y = y + jnp.einsum("bihd,bhde->bihe", qb, C) * w_st[..., None]
        # normalizer: q.n_i = row-sums of S plus the carried-state part
        qn = jnp.swapaxes(jnp.sum(S, axis=-1), 1, 2) \
            + jnp.einsum("bihd,bhd->bih", qb, n) * w_st
        m_i = F + M
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))
        y = y / denom[..., None]
        # chunk-final state update
        F_last = F[:, -1, :]                                 # (B,H)
        m_new = F_last + jnp.maximum(m, jnp.max(p, axis=1))
        w_c = jnp.exp(m + F_last - m_new)                    # carry decay
        w_j = jnp.exp(F_last[:, None] + p - m_new[:, None])  # (B,Q,H)
        C_new = C * w_c[..., None, None] + jnp.einsum(
            "bjhd,bjhe,bjh->bhde", kb, vb, w_j)
        n_new = n * w_c[..., None] + jnp.einsum("bjhd,bjh->bhd", kb, w_j)
        return (C_new, n_new, m_new), y

    (Cf, nf, mf), ys = jax.lax.scan(body, (C0, n0, m0),
                                    (qc, kc, vc, lic, lfc))
    y = ys.swapaxes(0, 1).reshape(b, Lp, H, hd)[:, :L]
    return y, (Cf, nf, mf)


def apply_mlstm(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    d_inner, nh, hd = _mlstm_dims(cfg)
    b, L, _ = x.shape
    h = apply_norm(p["ln"], x, cfg)
    up = apply_linear(p["up"], h, cfg.cim, compute_dtype=cdt(cfg))
    u, z = jnp.split(up, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    uc, new_conv = _causal_conv1d(u.astype(jnp.float32),
                                  p["conv_w"].astype(jnp.float32),
                                  p["conv_b"], conv_state)
    uc = uc.astype(cdt(cfg))
    q = apply_linear(p["wq"], uc, cfg.cim, compute_dtype=cdt(cfg)
                     ).reshape(b, L, nh, hd)
    k = apply_linear(p["wk"], uc, cfg.cim, compute_dtype=cdt(cfg)
                     ).reshape(b, L, nh, hd)
    v = apply_linear(p["wv"], u, cfg.cim, compute_dtype=cdt(cfg)
                     ).reshape(b, L, nh, hd)
    gates = apply_linear(p["w_if"], u.astype(jnp.float32), None,
                         compute_dtype=jnp.float32)
    li, lf_pre = jnp.split(gates, 2, axis=-1)                 # (B,L,nh)
    lf = jax.nn.log_sigmoid(lf_pre)

    carry = state["cell"] if state is not None else None
    y, new_cell = _mlstm_chunked(q, k, v, li, lf, cfg.ssm.chunk, carry)
    y = y.reshape(b, L, d_inner).astype(jnp.float32)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = y * p["out_norm"]["scale"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = apply_linear(p["down"], y.astype(cdt(cfg)), cfg.cim,
                       compute_dtype=cdt(cfg))
    new_state = ({"conv": new_conv, "cell": new_cell}
                 if state is not None else None)
    return x + out, new_state


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    nh = cfg.ssm.n_slstm_heads
    hd = d // nh
    dt = pdt(cfg)
    f_ff = (4 * d) // 3
    return {
        "ln": norm_specs(cfg),
        "wx": linear_specs(d, 4 * d, cim=cfg.cim, in_axis="embed",
                           out_axis="mlp", dtype=dt),
        "r": ParamSpec((4, nh, hd, hd), jnp.float32, "fan_in:1.0",
                       (None, None, None, None)),
        "bias": ParamSpec((4, d), jnp.float32, "zeros", (None, "embed")),
        "ln_ffn": norm_specs(cfg),
        "ffn_up": linear_specs(d, 2 * f_ff, cim=cfg.cim, in_axis="embed",
                               out_axis="mlp", dtype=dt),
        "ffn_down": linear_specs(f_ff, d, cim=cfg.cim, in_axis="mlp",
                                 out_axis="embed", dtype=dt),
    }


def apply_slstm(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    d = cfg.d_model
    nh = cfg.ssm.n_slstm_heads
    hd = d // nh
    b, L, _ = x.shape
    xin = apply_norm(p["ln"], x, cfg)
    wx = apply_linear(p["wx"], xin, cfg.cim, compute_dtype=cdt(cfg)
                      ).astype(jnp.float32)
    wx = wx + p["bias"].reshape(1, 1, 4 * d)
    wz, wi, wf, wo = jnp.split(wx, 4, axis=-1)                # (B,L,d)

    if state is None:
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.full((b, nh, hd), 1e-6, jnp.float32)
        m0 = jnp.full((b, nh, hd), -1e30, jnp.float32)
    else:
        h0, c0, n0, m0 = (state["h"], state["c"], state["n"], state["m"])

    r = p["r"]

    def step(carry, inp):
        h, c, n, m = carry
        z_t, i_t, f_t, o_t = inp                              # (B, d) each
        rec = lambda g: jnp.einsum("bhk,hkj->bhj", h, r[g])
        zt = jnp.tanh(z_t.reshape(b, nh, hd) + rec(0))
        it = i_t.reshape(b, nh, hd) + rec(1)
        ft = jax.nn.log_sigmoid(f_t.reshape(b, nh, hd) + rec(2))
        ot = jax.nn.sigmoid(o_t.reshape(b, nh, hd) + rec(3))
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    xs = (wz.swapaxes(0, 1), wi.swapaxes(0, 1), wf.swapaxes(0, 1),
          wo.swapaxes(0, 1))
    (hf, cf, nf, mf), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    y = hs.swapaxes(0, 1).reshape(b, L, d)

    out = x + y.astype(cdt(cfg))
    # gated FFN (GeGLU, 4/3 expansion)
    z2 = apply_norm(p["ln_ffn"], out, cfg)
    up = apply_linear(p["ffn_up"], z2, cfg.cim, compute_dtype=cdt(cfg))
    g, u = jnp.split(up, 2, axis=-1)
    ff = apply_linear(p["ffn_down"],
                      jax.nn.gelu(g.astype(jnp.float32)).astype(cdt(cfg)) * u,
                      cfg.cim, compute_dtype=cdt(cfg))
    out = out + ff
    new_state = ({"h": hf, "c": cf, "n": nf, "m": mf}
                 if state is not None else None)
    return out, new_state


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------

def _layer_kinds(cfg: ModelConfig):
    every = cfg.ssm.slstm_every
    return ["slstm" if every and (i % every == every - 1) else "mlstm"
            for i in range(cfg.n_layers)]


def specs(cfg: ModelConfig) -> Dict:
    kinds = _layer_kinds(cfg)
    n_m = kinds.count("mlstm")
    n_s = kinds.count("slstm")
    sp: Dict = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), pdt(cfg), "normal:0.02",
                           ("vocab", "embed")),
        "ln_f": norm_specs(cfg),
        "mlstm_layers": stack_specs(mlstm_specs(cfg), n_m),
        "lm_head": linear_specs(cfg.d_model, cfg.vocab, in_axis="embed",
                                out_axis="vocab", dtype=pdt(cfg),
                                init="normal:0.02"),
    }
    if n_s:
        sp["slstm_layers"] = stack_specs(slstm_specs(cfg), n_s)
    return sp


def _iterate(params, x, cfg, states):
    """Interleave mLSTM/sLSTM blocks in config order (unrolled: the two
    stacks are inhomogeneous; sLSTM layers are few)."""
    kinds = _layer_kinds(cfg)
    mi = si = 0
    new_states: Dict = {"mlstm": [], "slstm": []}
    for kind in kinds:
        if kind == "mlstm":
            p_i = jax.tree.map(lambda a: a[mi], params["mlstm_layers"])
            st = None if states is None else jax.tree.map(
                lambda a: a[mi], states["mlstm"])
            fn = jax.checkpoint(partial(apply_mlstm, cfg=cfg)) if cfg.remat \
                else partial(apply_mlstm, cfg=cfg)
            x, ns = fn(p_i, x, state=st)
            new_states["mlstm"].append(ns)
            mi += 1
        else:
            p_i = jax.tree.map(lambda a: a[si], params["slstm_layers"])
            st = None if states is None else jax.tree.map(
                lambda a: a[si], states["slstm"])
            fn = jax.checkpoint(partial(apply_slstm, cfg=cfg)) if cfg.remat \
                else partial(apply_slstm, cfg=cfg)
            x, ns = fn(p_i, x, state=st)
            new_states["slstm"].append(ns)
            si += 1
    if states is None:
        return x, None
    return x, {
        "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_states["mlstm"]),
        "slstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_states["slstm"]),
    }


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds=None) -> jnp.ndarray:
    x = params["embed"][tokens].astype(cdt(cfg))
    x, _ = _iterate(params, x, cfg, None)
    x = apply_norm(params["ln_f"], x, cfg)
    return apply_linear(params["lm_head"], x, None, compute_dtype=cdt(cfg))


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    d_inner, nh, hd = _mlstm_dims(cfg)
    kinds = _layer_kinds(cfg)
    n_m, n_s = kinds.count("mlstm"), kinds.count("slstm")
    d = cfg.d_model
    nsh = cfg.ssm.n_slstm_heads
    shd = d // nsh
    cache = {
        "mlstm": {
            "conv": jnp.zeros((n_m, batch, 3, d_inner), jnp.float32),
            "cell": (jnp.zeros((n_m, batch, nh, hd, hd), jnp.float32),
                     jnp.zeros((n_m, batch, nh, hd), jnp.float32),
                     jnp.full((n_m, batch, nh), -1e30, jnp.float32)),
        },
        "slstm": {
            "h": jnp.zeros((n_s, batch, nsh, shd), jnp.float32),
            "c": jnp.zeros((n_s, batch, nsh, shd), jnp.float32),
            "n": jnp.full((n_s, batch, nsh, shd), 1e-6, jnp.float32),
            "m": jnp.full((n_s, batch, nsh, shd), -1e30, jnp.float32),
        },
    }
    return cache


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    x = params["embed"][tokens].astype(cdt(cfg))
    x, new_cache = _iterate(params, x, cfg, cache)
    x = apply_norm(params["ln_f"], x, cfg)
    return apply_linear(params["lm_head"], x, None,
                        compute_dtype=cdt(cfg)), new_cache
