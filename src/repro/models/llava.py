"""LLaVA-NeXT-style VLM: Mistral-7B text backbone with a patch-embedding
STUB frontend per the assignment — ``input_specs`` supplies precomputed
anyres patch embeddings (B, n_patches, frontend_dim); a 2-layer MLP
projector maps them into the LM embedding space and they are prepended to
the token embeddings. Loss masking of image positions is handled by the
trainer (labels = -100 on image slots).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec
from . import transformer
from .layers import cdt, pdt


def specs(cfg: ModelConfig) -> Dict:
    sp = transformer.specs(cfg)
    fd = cfg.frontend_dim or cfg.d_model
    sp["projector"] = {
        "fc1": linear_specs(fd, cfg.d_model, in_axis=None, out_axis="embed",
                            dtype=pdt(cfg)),
        "fc2": linear_specs(cfg.d_model, cfg.d_model, in_axis="embed",
                            out_axis="embed", dtype=pdt(cfg)),
    }
    return sp


def project_patches(params: Dict, patches: jnp.ndarray, cfg: ModelConfig):
    h = apply_linear(params["projector"]["fc1"], patches, None,
                     compute_dtype=cdt(cfg))
    h = jnp.where(h > 0, h, 0.0)  # relu? llava uses gelu
    return apply_linear(params["projector"]["fc2"], h, None,
                        compute_dtype=cdt(cfg))


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    img = None
    if extra_embeds is not None:
        img = project_patches(params, extra_embeds, cfg)
    return transformer.forward(params, tokens, cfg, extra_embeds=img)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    return transformer.init_cache(cfg, batch, max_len)


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    return transformer.decode_step(params, cache, tokens, cfg)
