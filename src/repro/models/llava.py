"""LLaVA-NeXT-style VLM: Mistral-7B text backbone with a patch frontend.
A 2-layer MLP projector maps patch embeddings into the LM embedding
space and they are prepended to the token embeddings. Loss masking of
image positions is handled by the trainer (labels = -100 on image slots).

Patch embeddings come from either
  * the STUB path — ``input_specs`` supplies precomputed anyres patch
    embeddings (B, n_patches, frontend_dim) — or
  * with ``cfg.conv_frontend``, a ViT-style non-overlapping patch-embed
    conv (kernel = stride = ``cfg.patch_size``) on raw images
    (B, H, W, 3), routed through the CIM conv path (the fused
    ``cim_conv_pallas`` kernel on packed configs). 4-D ``extra_embeds``
    selects the conv; 3-D stays the stub, so full configs are unchanged.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec
from . import transformer
from .layers import apply_conv, cdt, conv_specs, pdt


def specs(cfg: ModelConfig) -> Dict:
    sp = transformer.specs(cfg)
    fd = cfg.frontend_dim or cfg.d_model
    sp["projector"] = {
        "fc1": linear_specs(fd, cfg.d_model, in_axis=None, out_axis="embed",
                            dtype=pdt(cfg)),
        "fc2": linear_specs(cfg.d_model, cfg.d_model, in_axis="embed",
                            out_axis="embed", dtype=pdt(cfg)),
    }
    if cfg.conv_frontend:
        ps = cfg.patch_size
        sp["patch_embed"] = conv_specs(ps, ps, 3, fd, cim=cfg.cim)
    return sp


def embed_patches(params: Dict, images: jnp.ndarray, cfg: ModelConfig):
    """Raw images (B, H, W, 3) -> patch embeddings (B, n_patches, fd) via
    the non-overlapping patch-embed conv (kernel = stride = patch_size)."""
    ps = cfg.patch_size
    h = apply_conv(params["patch_embed"], images.astype(cdt(cfg)), cfg.cim,
                   stride=ps, padding="VALID", compute_dtype=cdt(cfg))
    return h.reshape(h.shape[0], -1, h.shape[-1])


def project_patches(params: Dict, patches: jnp.ndarray, cfg: ModelConfig):
    h = apply_linear(params["projector"]["fc1"], patches, None,
                     compute_dtype=cdt(cfg))
    h = jnp.where(h > 0, h, 0.0)  # relu? llava uses gelu
    return apply_linear(params["projector"]["fc2"], h, None,
                        compute_dtype=cdt(cfg))


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    img = None
    if extra_embeds is not None:
        if cfg.conv_frontend and extra_embeds.ndim == 4:
            extra_embeds = embed_patches(params, extra_embeds, cfg)
        img = project_patches(params, extra_embeds, cfg)
    return transformer.forward(params, tokens, cfg, extra_embeds=img)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    return transformer.init_cache(cfg, batch, max_len)


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    return transformer.decode_step(params, cache, tokens, cfg)
