"""Model registry: family name -> (specs, forward, init_cache, decode_step).

``get_model(cfg)`` resolves the family of a ModelConfig; every entry
shares the same functional interface so the trainer / server / dry-run
never special-case architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.configs.base import ModelConfig
from . import llava, transformer, whisper, xlstm, zamba2


@dataclasses.dataclass(frozen=True)
class ModelFns:
    specs: Callable
    forward: Callable            # (params, tokens, cfg, extra_embeds=None) -> logits
    init_cache: Optional[Callable]   # (cfg, batch, max_len) -> cache
    decode_step: Optional[Callable]  # (params, cache, tokens, cfg) -> (logits, cache)


_FAMILIES: Dict[str, ModelFns] = {
    "transformer": ModelFns(transformer.specs, transformer.forward,
                            transformer.init_cache, transformer.decode_step),
    "xlstm": ModelFns(xlstm.specs, xlstm.forward, xlstm.init_cache,
                      xlstm.decode_step),
    "zamba2": ModelFns(zamba2.specs, zamba2.forward, zamba2.init_cache,
                       zamba2.decode_step),
    "whisper": ModelFns(whisper.specs, whisper.forward, whisper.init_cache,
                        whisper.decode_step),
    "llava": ModelFns(llava.specs, llava.forward, llava.init_cache,
                      llava.decode_step),
}


def get_model(cfg: ModelConfig) -> ModelFns:
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown model family {cfg.family!r}; "
                       f"known: {sorted(_FAMILIES)}") from None
