"""Model registry: family name -> (specs, forward, init_cache, decode_step).

``get_model(cfg)`` resolves the family of a ModelConfig; every entry
shares the same functional interface so the trainer / server / dry-run
never special-case architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.configs.base import ModelConfig
from . import llava, transformer, whisper, xlstm, zamba2


@dataclasses.dataclass(frozen=True)
class ModelFns:
    specs: Callable
    forward: Callable            # (params, tokens, cfg, extra_embeds=None) -> logits
    init_cache: Optional[Callable]   # (cfg, batch, max_len) -> cache
    decode_step: Optional[Callable]  # (params, cache, tokens, cfg) -> (logits, cache)


_FAMILIES: Dict[str, ModelFns] = {
    "transformer": ModelFns(transformer.specs, transformer.forward,
                            transformer.init_cache, transformer.decode_step),
    "xlstm": ModelFns(xlstm.specs, xlstm.forward, xlstm.init_cache,
                      xlstm.decode_step),
    "zamba2": ModelFns(zamba2.specs, zamba2.forward, zamba2.init_cache,
                       zamba2.decode_step),
    "whisper": ModelFns(whisper.specs, whisper.forward, whisper.init_cache,
                        whisper.decode_step),
    "llava": ModelFns(llava.specs, llava.forward, llava.init_cache,
                      llava.decode_step),
}


def get_model(cfg: ModelConfig) -> ModelFns:
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown model family {cfg.family!r}; "
                       f"known: {sorted(_FAMILIES)}") from None


def frontend_input_shape(cfg: ModelConfig, batch: int):
    """Shape of the ``frontend`` batch entry a config's forward expects:
    raw conv-frontend input (log-mel frames / images) when
    ``cfg.conv_frontend``, stub embeddings otherwise; None for text-only
    models. Tests, examples and launchers build inputs from this so the
    stub-vs-conv decision lives in one place."""
    if cfg.n_frontend_tokens == 0 or cfg.family not in ("whisper", "llava"):
        return None
    fd = cfg.frontend_dim or cfg.d_model
    if not cfg.conv_frontend:
        return (batch, cfg.n_frontend_tokens, fd)
    if cfg.family == "whisper":
        # two raw frames per encoder token (the stride-2 conv2)
        return (batch, 2 * cfg.n_frontend_tokens, fd)
    side = int(round(cfg.n_frontend_tokens ** 0.5)) * cfg.patch_size
    return (batch, side, side, 3)
