"""Whisper-style encoder-decoder (arXiv:2212.04356): transformer backbone
only — the conv/log-mel audio frontend is a STUB per the assignment
(``input_specs`` supplies precomputed frame embeddings (B, n_frames, d)).

Encoder: bidirectional self-attention over frames + learned positions.
Decoder: causal self-attention (KV-cached) + cross-attention to the
encoder output (K/V computed once at prefill and cached).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec, stack_specs
from .layers import (apply_mlp, apply_norm, cdt, gqa_attend, gqa_specs,
                     mlp_specs, norm_specs, pdt)


def _enc_block_specs(cfg):
    return {"ln1": norm_specs(cfg), "attn": gqa_specs(cfg),
            "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}


def _dec_block_specs(cfg):
    return {"ln1": norm_specs(cfg), "self_attn": gqa_specs(cfg),
            "ln2": norm_specs(cfg), "cross_attn": gqa_specs(cfg),
            "ln3": norm_specs(cfg), "mlp": mlp_specs(cfg)}


def specs(cfg: ModelConfig) -> Dict:
    return {
        "enc_pos": ParamSpec((cfg.n_frontend_tokens, cfg.d_model), pdt(cfg),
                             "normal:0.01", (None, "embed")),
        "enc_layers": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
        "enc_ln_f": norm_specs(cfg),
        "embed": ParamSpec((cfg.vocab, cfg.d_model), pdt(cfg), "normal:0.02",
                           ("vocab", "embed")),
        "dec_pos": ParamSpec((cfg.max_seq, cfg.d_model), pdt(cfg),
                             "normal:0.01", (None, "embed")),
        "dec_layers": stack_specs(_dec_block_specs(cfg), cfg.n_layers),
        "dec_ln_f": norm_specs(cfg),
    }


def encode(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, n_frames, d) stub embeddings -> encoder states."""
    x = frames.astype(cdt(cfg)) + params["enc_pos"][None, :frames.shape[1]
                                                    ].astype(cdt(cfg))
    positions = jnp.arange(x.shape[1])

    def blk(p, x):
        h, _ = gqa_attend(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                          positions=positions, causal=False)
        x = x + h
        return x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)

    fn = jax.checkpoint(blk) if cfg.remat else blk

    if cfg.scan_layers:
        def body(carry, p):
            return fn(p, carry), None
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.enc_layers):
            x = fn(jax.tree.map(lambda a: a[i], params["enc_layers"]), x)
    return apply_norm(params["enc_ln_f"], x, cfg)


def _dec_block(p, x, cfg, positions, enc_out, cache):
    h, nc = gqa_attend(p["self_attn"], apply_norm(p["ln1"], x, cfg), cfg,
                       positions=positions, cache=cache)
    x = x + h
    h, _ = gqa_attend(p["cross_attn"], apply_norm(p["ln2"], x, cfg), cfg,
                      positions=positions, x_kv=enc_out, causal=False)
    x = x + h
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln3"], x, cfg), cfg)
    return x, nc


def decode(params: Dict, tokens: jnp.ndarray, enc_out: jnp.ndarray,
           cfg: ModelConfig, cache: Optional[Dict] = None,
           position_offset: jnp.ndarray | int = 0):
    b, t = tokens.shape
    if isinstance(position_offset, jnp.ndarray) and position_offset.ndim == 1:
        pos_idx = position_offset[:, None] + jnp.arange(t)[None]  # (B, t)
    else:
        pos_idx = position_offset + jnp.arange(t)
    x = params["embed"][tokens].astype(cdt(cfg)) \
        + params["dec_pos"][pos_idx].astype(cdt(cfg))
    positions = pos_idx
    blk = partial(_dec_block, cfg=cfg, enc_out=enc_out, positions=positions)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    if cfg.scan_layers:
        def body(carry, inp):
            p, c = inp
            y, nc = blk(p, carry, cache=c)
            return y, nc
        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    else:
        ncs = []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params["dec_layers"])
            c_i = None if cache is None else jax.tree.map(
                lambda a: a[i], cache)
            x, nc_i = blk(p_i, x, cache=c_i)
            ncs.append(nc_i)
        new_cache = (None if cache is None
                     else jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    x = apply_norm(params["dec_ln_f"], x, cfg)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(cdt(cfg)))
    return logits, new_cache


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Teacher-forced train step: extra_embeds = frame stub (B, F, d)."""
    enc_out = encode(params, extra_embeds, cfg)
    logits, _ = decode(params, tokens, enc_out, cfg, cache=None)
    return logits


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, kvh, hd), cdt(cfg)),
        "v": jnp.zeros((L, batch, max_len, kvh, hd), cdt(cfg)),
        "len": jnp.zeros((L, batch), jnp.int32),
        "enc_out": jnp.zeros((batch, cfg.n_frontend_tokens, cfg.d_model),
                             cdt(cfg)),
    }


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    sa = {"k": cache["k"], "v": cache["v"], "len": cache["len"]}
    logits, new_sa = decode(params, tokens, cache["enc_out"], cfg, cache=sa,
                            position_offset=cache["len"][0])
    new_cache = dict(new_sa)
    new_cache["enc_out"] = cache["enc_out"]
    return logits, new_cache
