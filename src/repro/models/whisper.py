"""Whisper-style encoder-decoder (arXiv:2212.04356).

Encoder: bidirectional self-attention over frames + learned positions.
Decoder: causal self-attention (KV-cached) + cross-attention to the
encoder output (K/V computed once at prefill and cached).

Frontend: with ``cfg.conv_frontend`` the paper-faithful two-conv stem
(GELU(conv k=3) -> GELU(conv k=3, stride 2)) runs on raw log-mel frames
(B, 2*n_frontend_tokens, n_mels=frontend_dim) through the CIM conv path
— on packed configs that is the fused ``cim_conv_pallas`` deploy kernel.
Stub inputs (precomputed (B, n_frames, d_model) frame embeddings) are
still accepted and bypass the stem, keyed on the trailing dim, so full
configs and existing launch cells are unchanged.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec, stack_specs
from .layers import (apply_conv, apply_mlp, apply_norm, cdt, conv_specs,
                     gqa_attend, gqa_specs, mlp_specs, norm_specs, pdt)


def _enc_block_specs(cfg):
    return {"ln1": norm_specs(cfg), "attn": gqa_specs(cfg),
            "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}


def _dec_block_specs(cfg):
    return {"ln1": norm_specs(cfg), "self_attn": gqa_specs(cfg),
            "ln2": norm_specs(cfg), "cross_attn": gqa_specs(cfg),
            "ln3": norm_specs(cfg), "mlp": mlp_specs(cfg)}


def specs(cfg: ModelConfig) -> Dict:
    sp = {
        "enc_pos": ParamSpec((cfg.n_frontend_tokens, cfg.d_model), pdt(cfg),
                             "normal:0.01", (None, "embed")),
        "enc_layers": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
        "enc_ln_f": norm_specs(cfg),
        "embed": ParamSpec((cfg.vocab, cfg.d_model), pdt(cfg), "normal:0.02",
                           ("vocab", "embed")),
        "dec_pos": ParamSpec((cfg.max_seq, cfg.d_model), pdt(cfg),
                             "normal:0.01", (None, "embed")),
        "dec_layers": stack_specs(_dec_block_specs(cfg), cfg.n_layers),
        "dec_ln_f": norm_specs(cfg),
    }
    if cfg.conv_frontend:
        n_mels = cfg.frontend_dim or cfg.d_model
        sp["frontend"] = {
            "conv1": conv_specs(1, 3, n_mels, cfg.d_model, cim=cfg.cim,
                                out_axis="embed"),
            "conv2": conv_specs(1, 3, cfg.d_model, cfg.d_model, cim=cfg.cim,
                                out_axis="embed"),
        }
    return sp


def _conv_stem(params: Dict, mel: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Raw log-mel (B, 2*n_frontend_tokens, n_mels) -> (B, F, d_model)
    via the paper-faithful conv stem (time viewed as the W axis of an
    H=1 NHWC image; stride 2 on conv2 halves the frame rate)."""
    h = mel.astype(cdt(cfg))[:, None]                   # (B, 1, 2F, mels)
    h = apply_conv(params["frontend"]["conv1"], h, cfg.cim, stride=1,
                   padding="SAME", compute_dtype=cdt(cfg))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cdt(cfg))
    h = apply_conv(params["frontend"]["conv2"], h, cfg.cim, stride=2,
                   padding="SAME", compute_dtype=cdt(cfg))
    return jax.nn.gelu(h.astype(jnp.float32)).astype(cdt(cfg))[:, 0]


def encode(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: raw log-mel (B, 2F, n_mels) when the conv frontend is on
    (trailing dim != d_model), else stub embeddings (B, F, d) -> encoder
    states."""
    if cfg.conv_frontend and frames.shape[-1] != cfg.d_model:
        frames = _conv_stem(params, frames, cfg)
    x = frames.astype(cdt(cfg)) + params["enc_pos"][None, :frames.shape[1]
                                                    ].astype(cdt(cfg))
    positions = jnp.arange(x.shape[1])

    def blk(p, x):
        h, _ = gqa_attend(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                          positions=positions, causal=False)
        x = x + h
        return x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)

    fn = jax.checkpoint(blk) if cfg.remat else blk

    if cfg.scan_layers:
        def body(carry, p):
            return fn(p, carry), None
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.enc_layers):
            x = fn(jax.tree.map(lambda a: a[i], params["enc_layers"]), x)
    return apply_norm(params["enc_ln_f"], x, cfg)


def _dec_block(p, x, cfg, positions, enc_out, cache):
    h, nc = gqa_attend(p["self_attn"], apply_norm(p["ln1"], x, cfg), cfg,
                       positions=positions, cache=cache)
    x = x + h
    h, _ = gqa_attend(p["cross_attn"], apply_norm(p["ln2"], x, cfg), cfg,
                      positions=positions, x_kv=enc_out, causal=False)
    x = x + h
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln3"], x, cfg), cfg)
    return x, nc


def decode(params: Dict, tokens: jnp.ndarray, enc_out: jnp.ndarray,
           cfg: ModelConfig, cache: Optional[Dict] = None,
           position_offset: jnp.ndarray | int = 0):
    b, t = tokens.shape
    if isinstance(position_offset, jnp.ndarray) and position_offset.ndim == 1:
        pos_idx = position_offset[:, None] + jnp.arange(t)[None]  # (B, t)
    else:
        pos_idx = position_offset + jnp.arange(t)
    x = params["embed"][tokens].astype(cdt(cfg)) \
        + params["dec_pos"][pos_idx].astype(cdt(cfg))
    positions = pos_idx
    blk = partial(_dec_block, cfg=cfg, enc_out=enc_out, positions=positions)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    if cfg.scan_layers:
        def body(carry, inp):
            p, c = inp
            y, nc = blk(p, carry, cache=c)
            return y, nc
        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    else:
        ncs = []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params["dec_layers"])
            c_i = None if cache is None else jax.tree.map(
                lambda a: a[i], cache)
            x, nc_i = blk(p_i, x, cache=c_i)
            ncs.append(nc_i)
        new_cache = (None if cache is None
                     else jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    x = apply_norm(params["dec_ln_f"], x, cfg)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(cdt(cfg)))
    return logits, new_cache


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Teacher-forced train step: extra_embeds = frame stub (B, F, d)."""
    enc_out = encode(params, extra_embeds, cfg)
    logits, _ = decode(params, tokens, enc_out, cfg, cache=None)
    return logits


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, kvh, hd), cdt(cfg)),
        "v": jnp.zeros((L, batch, max_len, kvh, hd), cdt(cfg)),
        "len": jnp.zeros((L, batch), jnp.int32),
        "enc_out": jnp.zeros((batch, cfg.n_frontend_tokens, cfg.d_model),
                             cdt(cfg)),
    }


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    sa = {"k": cache["k"], "v": cache["v"], "len": cache["len"]}
    logits, new_sa = decode(params, tokens, cache["enc_out"], cfg, cache=sa,
                            position_offset=cache["len"][0])
    new_cache = dict(new_sa)
    new_cache["enc_out"] = cache["enc_out"]
    return logits, new_cache
