"""Generic decoder-only LM covering the dense GQA family (llama3, granite,
qwen3 w/ qk-norm, olmo w/ non-parametric LN), MLA (deepseek-v3) and MoE
(moonshot, deepseek) variants — one spec/apply pair driven by ModelConfig.

Layers run under ``lax.scan`` with stacked parameters (small HLO, fast
compiles at 61 layers) and optional remat. Decode maintains per-layer KV
caches (latent caches for MLA) scanned alongside the parameters.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec, constrain, stack_specs
from .layers import (apply_mlp, apply_moe, apply_norm, cdt, gqa_attend,
                     gqa_specs, mla_attend, mla_specs, mlp_specs, moe_specs,
                     norm_specs, pdt)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _block_specs(cfg: ModelConfig, *, moe: bool, dense_d_ff: int = 0) -> Dict:
    sp = {
        "ln1": norm_specs(cfg),
        "ln2": norm_specs(cfg),
        "attn": mla_specs(cfg) if cfg.mla is not None else gqa_specs(cfg),
    }
    if moe:
        sp["moe"] = moe_specs(cfg)
    else:
        sp["mlp"] = mlp_specs(cfg, d_ff=dense_d_ff or cfg.d_ff)
    return sp


def specs(cfg: ModelConfig) -> Dict:
    sp: Dict = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), pdt(cfg), "normal:0.02",
                           ("vocab", "embed")),
        "ln_f": norm_specs(cfg),
    }
    n_moe = 0
    if cfg.moe is not None:
        n_dense = cfg.moe.n_dense_layers
        n_moe = cfg.n_layers - n_dense
        if n_dense:
            sp["dense_layers"] = stack_specs(
                _block_specs(cfg, moe=False,
                             dense_d_ff=cfg.moe.dense_d_ff or cfg.d_ff),
                n_dense)
        sp["moe_layers"] = stack_specs(_block_specs(cfg, moe=True), n_moe)
    else:
        sp["layers"] = stack_specs(_block_specs(cfg, moe=False), cfg.n_layers)
    if not cfg.tie_embeddings:
        sp["lm_head"] = linear_specs(
            cfg.d_model, cfg.vocab,
            cim=cfg.cim if cfg.cim_lm_head else None,
            in_axis="embed", out_axis="vocab", dtype=pdt(cfg),
            init="normal:0.02")
    return sp


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(p: Dict, x, cfg: ModelConfig, positions, cache, moe: bool):
    h, new_cache = (mla_attend(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                               positions=positions, cache=cache)
                    if cfg.mla is not None else
                    gqa_attend(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                               positions=positions, cache=cache))
    x = x + h
    z = apply_norm(p["ln2"], x, cfg)
    x = x + (apply_moe(p["moe"], z, cfg) if moe else apply_mlp(p["mlp"], z, cfg))
    x = constrain(x, ("batch", None, None))
    return x, new_cache


def _run_stack(layer_params, x, cfg, positions, caches, moe: bool):
    """Scan (or unrolled loop) over a homogeneous stack of blocks."""
    blk = partial(_block, cfg=cfg, positions=positions, moe=moe)
    if cfg.remat:
        # full recompute per layer: only the scan-carried residual stream is
        # saved (d_model wide) — the policy that fits 1M-token batches.
        blk = jax.checkpoint(blk)

    if cfg.scan_layers:
        def body(carry, inp):
            p, c = inp
            y, nc = blk(p, carry, cache=c)
            return y, nc
        x, new_caches = jax.lax.scan(body, x, (layer_params, caches))
        return x, new_caches
    n = jax.tree_util.tree_leaves(layer_params)[0].shape[0]
    new_caches = []
    for i in range(n):
        p_i = jax.tree.map(lambda a: a[i], layer_params)
        c_i = None if caches is None else jax.tree.map(lambda a: a[i], caches)
        x, nc = blk(p_i, x, cache=c_i)
        new_caches.append(nc)
    if caches is None:
        return x, None
    return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)


def _embed(params, tokens, cfg, extra_embeds):
    x = params["embed"][tokens].astype(cdt(cfg))
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt(cfg)), x], axis=1)
    return x


def _logits(params, x, cfg):
    x = apply_norm(params["ln_f"], x, cfg)
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, params["embed"].astype(cdt(cfg)))
    return apply_linear(params["lm_head"], x,
                        cfg.cim if cfg.cim_lm_head else None,
                        compute_dtype=cdt(cfg))


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence forward (train / prefill scoring): tokens (B, T) ->
    logits (B, T', vocab). extra_embeds (B, Tp, D) are prepended (VLM)."""
    x = _embed(params, tokens, cfg, extra_embeds)
    positions = jnp.arange(x.shape[1])
    x = constrain(x, ("batch", None, None))
    if cfg.moe is not None:
        if "dense_layers" in params:
            x, _ = _run_stack(params["dense_layers"], x, cfg, positions, None, False)
        x, _ = _run_stack(params["moe_layers"], x, cfg, positions, None, True)
    else:
        x, _ = _run_stack(params["layers"], x, cfg, positions, None, False)
    return _logits(params, x, cfg)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Per-layer decode caches stacked on a leading layer axis."""
    def kv(n_layers):
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jnp.zeros((n_layers, batch, max_len, kvh, hd), jnp.int8),
                "v": jnp.zeros((n_layers, batch, max_len, kvh, hd), jnp.int8),
                "k_scale": jnp.zeros((n_layers, batch, max_len, kvh),
                                     jnp.float32),
                "v_scale": jnp.zeros((n_layers, batch, max_len, kvh),
                                     jnp.float32),
                "len": jnp.zeros((n_layers, batch), jnp.int32),
            }
        return {
            "k": jnp.zeros((n_layers, batch, max_len, kvh, hd), cdt(cfg)),
            "v": jnp.zeros((n_layers, batch, max_len, kvh, hd), cdt(cfg)),
            "len": jnp.zeros((n_layers, batch), jnp.int32),
        }
    def mla(n_layers):
        m = cfg.mla
        return {
            "ckv": jnp.zeros((n_layers, batch, max_len, m.kv_lora_rank), cdt(cfg)),
            "krope": jnp.zeros((n_layers, batch, max_len, 1, m.qk_rope_dim), cdt(cfg)),
            "len": jnp.zeros((n_layers, batch), jnp.int32),
        }
    make = mla if cfg.mla is not None else kv
    if cfg.moe is not None:
        n_dense = cfg.moe.n_dense_layers
        out = {"moe_layers": make(cfg.n_layers - n_dense)}
        if n_dense:
            out["dense_layers"] = make(n_dense)
        return out
    return {"layers": make(cfg.n_layers)}


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: tokens (B, 1) + caches -> (logits (B,1,V), caches)."""
    x = params["embed"][tokens].astype(cdt(cfg))
    new_cache: Dict = {}
    # all layers share the same current length
    first = next(iter(cache.values()))
    positions = first["len"][0][:, None] + jnp.arange(tokens.shape[1])[None]
    if cfg.moe is not None:
        if "dense_layers" in params:
            x, nc = _run_stack(params["dense_layers"], x, cfg, positions,
                               cache["dense_layers"], False)
            new_cache["dense_layers"] = nc
        x, nc = _run_stack(params["moe_layers"], x, cfg, positions,
                           cache["moe_layers"], True)
        new_cache["moe_layers"] = nc
    else:
        x, nc = _run_stack(params["layers"], x, cfg, positions,
                           cache["layers"], False)
        new_cache["layers"] = nc
    return _logits(params, x, cfg), new_cache
