"""ResNet-20 (CIFAR) / ResNet-18 (ImageNet) on the CIM convolution
framework — the paper's own evaluation architectures (Table II).

Every conv routes through the CIM conv forward (``repro.api.conv2d``:
stretched-kernel tiling + group conv + bit-split + column-wise W/psum
quantization). Following common CIM QAT practice (and the paper's
settings), the first conv and the final FC layer stay full-precision.
BatchNorm carries explicit running statistics in a separate ``state``
tree (functional; trainer threads it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cim_conv import _conv_forward, _init_conv
from repro.core.cim_linear import CIMConfig, _deprecated


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depth: int                    # 20 (cifar) or 18 (imagenet-style)
    n_classes: int
    widths: Tuple[int, ...] = (16, 32, 64)
    in_hw: int = 32
    cim: CIMConfig = dataclasses.field(default_factory=CIMConfig)
    bn_momentum: float = 0.9

    @property
    def blocks_per_stage(self) -> int:
        if self.depth == 20:
            return 3
        return 2                  # resnet18: 2 basic blocks per stage


def _bn_init(c):
    return ({"scale": jnp.ones((c,), jnp.float32),
             "bias": jnp.zeros((c,), jnp.float32)},
            {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)})


def _bn_apply(p, s, x, train: bool, momentum: float):
    xf = x.astype(jnp.float32)
    if train:
        mu = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mu,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        mu, var, new_s = s["mean"], s["var"], s
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y.astype(x.dtype), new_s


def init(key: jax.Array, cfg: ResNetConfig):
    """Returns (params, bn_state)."""
    widths = cfg.widths if cfg.depth == 20 else (64, 128, 256, 512)
    nb = cfg.blocks_per_stage
    keys = iter(jax.random.split(key, 4 + 2 * len(widths) * nb * 2))
    params: Dict = {}
    state: Dict = {}
    c_in = 3
    # stem conv: full precision (standard CIM QAT practice)
    fp = cfg.cim.replace(enabled=False)
    params["stem"] = _init_conv(next(keys), 3, 3, c_in, widths[0], fp)
    params["stem_bn"], state["stem_bn"] = _bn_init(widths[0])
    c_in = widths[0]
    for si, w in enumerate(widths):
        for bi in range(nb):
            name = f"s{si}b{bi}"
            stride = 2 if (bi == 0 and si > 0) else 1
            blk: Dict = {
                "conv1": _init_conv(next(keys), 3, 3, c_in, w, cfg.cim),
                "conv2": _init_conv(next(keys), 3, 3, w, w, cfg.cim),
            }
            bst: Dict = {}
            blk["bn1"], bst["bn1"] = _bn_init(w)
            blk["bn2"], bst["bn2"] = _bn_init(w)
            if stride != 1 or c_in != w:
                blk["proj"] = _init_conv(next(keys), 1, 1, c_in, w, cfg.cim)
                blk["bn_p"], bst["bn_p"] = _bn_init(w)
            params[name] = blk
            state[name] = bst
            c_in = w
    params["fc"] = {
        "w": (jax.random.normal(next(keys), (c_in, cfg.n_classes), jnp.float32)
              / jnp.sqrt(c_in)),
        "b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }
    return params, state


def pack_deploy(params: Dict, cfg: ResNetConfig) -> Dict:
    """Deprecated: use ``repro.api.pack_model(params, cfg.cim)`` (or
    ``repro.api.model_artifact`` for a saveable ``DeployArtifact``). The
    generic tree walk packs every CIM conv to int digit planes; the
    full-precision stem, BN and FC pass through unchanged."""
    _deprecated("models.resnet.pack_deploy", "repro.api.pack_model")
    from repro.api import pack_model
    return pack_model(params, cfg.cim)


def conv_layer_names(cfg: ResNetConfig) -> Tuple[Tuple[str, int], ...]:
    """Ordered (layer_name, stride) pairs for every CIM conv in forward
    order — "s0b1.conv2", "s1b0.proj", ... The single source of layer
    identity shared by ``variation_keys``, ``forward(return_taps=True)``
    and the robustness harness's per-layer attribution."""
    widths = cfg.widths if cfg.depth == 20 else (64, 128, 256, 512)
    nb = cfg.blocks_per_stage
    out = []
    c_in = widths[0]
    for si, w in enumerate(widths):
        for bi in range(nb):
            name = f"s{si}b{bi}"
            stride = 2 if (bi == 0 and si > 0) else 1
            out.append((f"{name}.conv1", stride))
            out.append((f"{name}.conv2", 1))
            if stride != 1 or c_in != w:
                out.append((f"{name}.proj", stride))
            c_in = w
    return tuple(out)


def variation_keys(key: Optional[jax.Array], cfg: ResNetConfig
                   ) -> Optional[Dict[str, jax.Array]]:
    """Per-layer variation keys, {layer_name: key}. ``forward`` consumes
    exactly these, so per-layer re-evaluation (error attribution) sees the
    same device noise as the end-to-end forward pass."""
    if key is None:
        return None
    names = [n for n, _ in conv_layer_names(cfg)]
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def forward(params: Dict, state: Dict, x: jnp.ndarray, cfg: ResNetConfig,
            *, train: bool, variation_key: Optional[jax.Array] = None,
            variation_std=None, return_taps: bool = False):
    """x: (B, H, W, 3) -> (logits, new_bn_state).

    ``variation_key``/``variation_std`` evaluate one Monte-Carlo cell-
    noise realization (per-layer keys from ``variation_keys``; std may be
    a traced scalar so sigma sweeps don't recompile). With
    ``return_taps=True`` also returns {layer_name: conv input activation}
    — the hook the robustness harness uses for per-layer attribution.
    """
    widths = cfg.widths if cfg.depth == 20 else (64, 128, 256, 512)
    nb = cfg.blocks_per_stage
    new_state: Dict = {}
    taps: Dict[str, jnp.ndarray] = {}
    fp = cfg.cim.replace(enabled=False)
    h = _conv_forward(x, params["stem"], fp, compute_dtype=jnp.float32)
    h, new_state["stem_bn"] = _bn_apply(params["stem_bn"], state["stem_bn"],
                                        h, train, cfg.bn_momentum)
    h = jax.nn.relu(h)
    vkeys = variation_keys(variation_key, cfg) or {}
    for si, w in enumerate(widths):
        for bi in range(nb):
            name = f"s{si}b{bi}"
            blk, bst = params[name], state[name]
            nst: Dict = {}
            stride = 2 if (bi == 0 and si > 0) else 1
            if return_taps:
                taps[f"{name}.conv1"] = h
            y = _conv_forward(h, blk["conv1"], cfg.cim, stride=stride,
                              variation_key=vkeys.get(f"{name}.conv1"),
                              variation_std=variation_std,
                              compute_dtype=jnp.float32)
            y, nst["bn1"] = _bn_apply(blk["bn1"], bst["bn1"], y, train,
                                      cfg.bn_momentum)
            y = jax.nn.relu(y)
            if return_taps:
                taps[f"{name}.conv2"] = y
            y = _conv_forward(y, blk["conv2"], cfg.cim,
                              variation_key=vkeys.get(f"{name}.conv2"),
                              variation_std=variation_std,
                              compute_dtype=jnp.float32)
            y, nst["bn2"] = _bn_apply(blk["bn2"], bst["bn2"], y, train,
                                      cfg.bn_momentum)
            if "proj" in blk:
                if return_taps:
                    taps[f"{name}.proj"] = h
                sc = _conv_forward(h, blk["proj"], cfg.cim, stride=stride,
                                   variation_key=vkeys.get(f"{name}.proj"),
                                   variation_std=variation_std,
                                   compute_dtype=jnp.float32)
                sc, nst["bn_p"] = _bn_apply(blk["bn_p"], bst["bn_p"], sc,
                                            train, cfg.bn_momentum)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
            new_state[name] = nst
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params["fc"]["w"] + params["fc"]["b"]
    if return_taps:
        return logits, new_state, taps
    return logits, new_state


def calibrate(params: Dict, state: Dict, x: jnp.ndarray, cfg: ResNetConfig):
    """Run one forward pass, calibrating every CIM conv's s_a / s_p from
    the activations that actually reach it."""
    from repro.core.cim_conv import _calibrate_conv
    widths = cfg.widths if cfg.depth == 20 else (64, 128, 256, 512)
    nb = cfg.blocks_per_stage
    fp = cfg.cim.replace(enabled=False)
    p = {k: (dict(v) if isinstance(v, dict) else v) for k, v in params.items()}
    h = _conv_forward(x, p["stem"], fp, compute_dtype=jnp.float32)
    h, _ = _bn_apply(p["stem_bn"], state["stem_bn"], h, True, cfg.bn_momentum)
    h = jax.nn.relu(h)
    for si, w in enumerate(widths):
        for bi in range(nb):
            name = f"s{si}b{bi}"
            blk = dict(p[name])
            bst = state[name]
            stride = 2 if (bi == 0 and si > 0) else 1
            blk["conv1"] = _calibrate_conv(h, blk["conv1"], cfg.cim,
                                           stride=stride)
            y = _conv_forward(h, blk["conv1"], cfg.cim, stride=stride,
                              compute_dtype=jnp.float32)
            y, _ = _bn_apply(blk["bn1"], bst["bn1"], y, True, cfg.bn_momentum)
            y = jax.nn.relu(y)
            blk["conv2"] = _calibrate_conv(y, blk["conv2"], cfg.cim)
            y = _conv_forward(y, blk["conv2"], cfg.cim, compute_dtype=jnp.float32)
            y, _ = _bn_apply(blk["bn2"], bst["bn2"], y, True, cfg.bn_momentum)
            if "proj" in blk:
                blk["proj"] = _calibrate_conv(h, blk["proj"], cfg.cim,
                                              stride=stride)
                sc = _conv_forward(h, blk["proj"], cfg.cim, stride=stride,
                                   compute_dtype=jnp.float32)
                sc, _ = _bn_apply(blk["bn_p"], bst["bn_p"], sc, True,
                                  cfg.bn_momentum)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
            p[name] = blk
    return p
