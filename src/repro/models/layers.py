"""Shared transformer building blocks: norms, RoPE, GQA/MLA attention
(KV-chunked flash-style for long contexts), SwiGLU/GELU MLPs, and the
expert-parallel MoE block. All stored-weight matmuls route through
``apply_linear`` so the paper's CIM quantization applies uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec, constrain, shard_map

NEG_INF = -1e30


def cdt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def pdt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# conv (CIM-aware entry point, mirrors nn.linear.apply_linear)
# ---------------------------------------------------------------------------

def conv_specs(
    kh: int, kw: int, c_in: int, c_out: int,
    *,
    cim: Optional["CIMConfig"] = None,
    out_axis: Optional[str] = None,
    dtype=jnp.float32,
) -> Dict[str, ParamSpec]:
    """ParamSpecs for a CIM conv layer (HWIO weight + paper scale factors).

    In deploy mode the weight exists ONLY as the packed 6-D digit planes
    the fused Pallas conv kernel consumes (see repro.api.pack_conv); emulate
    keeps the float HWIO weight for QAT. The out_axis lands on the planes'
    last (C_out) axis — the column-shard axis of mesh-aware deploy serving
    (DESIGN.md §10), matching ``DeployArtifact.shard``'s placement."""
    from repro.api.backends import (conv_plane_tiling, has_own_pack,
                                    is_packed, plane_bits)
    from repro.core.granularity import conv_tiling

    packed = is_packed(cim)
    if packed:
        # plane geometry is the backend's (binary: S=1 sign planes)
        t, cpa = conv_plane_tiling(cim, kh, kw, c_in, c_out)
        own_pack = has_own_pack(cim)
        if own_pack:
            cpa_s, store = cpa, cim.store_dtype()
        else:
            # standard v4 pack: int4 planes store nibble-packed along the
            # channel-slice axis and carry a w_occ map (DESIGN.md §14)
            from repro.core.nibble import stored_rows
            cpa_s, store = stored_rows(cpa, cim.store_dtype())
        specs = {"w_digits": ParamSpec(
            (t.n_split, t.k_tiles, kh, kw, cpa_s, c_out), store,
            "zeros", (None, None, None, None, None, out_axis))}
        if not own_pack:
            specs["w_occ"] = ParamSpec(
                (t.n_split, t.k_tiles, c_out), jnp.uint8, "zeros",
                (None, None, out_axis))
    else:
        # He init over the full receptive field (kh*kw*c_in), matching
        # init_cim_conv — ParamSpec's "fan_in" string would only see c_in
        fan = kh * kw * c_in
        he = lambda k, s, d: (jax.random.normal(k, s, jnp.float32)
                              * jnp.sqrt(2.0 / fan)).astype(d)
        specs = {"w": ParamSpec((kh, kw, c_in, c_out), dtype, he,
                                (None, None, None, out_axis))}
    if cim is not None and cim.enabled:
        if packed and plane_bits(cim) != (cim.weight_bits, cim.cell_bits):
            # plane-geometry backends (binary) store FULL column-
            # granularity scales (see nn.linear.linear_specs)
            from repro.core.granularity import Granularity
            t, _ = conv_plane_tiling(cim, kh, kw, c_in, c_out)
            wg = t.weight_scale_shape(Granularity.COLUMN)
            pg = t.psum_scale_shape(Granularity.COLUMN)
        else:
            t, _ = conv_tiling(kh, kw, c_in, c_out, cim.array_rows,
                               cim.array_cols, cim.weight_bits, cim.cell_bits)
            wg = t.weight_scale_shape(cim.weight_granularity)
            pg = t.psum_scale_shape(cim.psum_granularity)
        specs["s_w"] = ParamSpec(wg, jnp.float32, "const:0.05",
                                 (None, out_axis if wg[1] == c_out else None))
        specs["s_p"] = ParamSpec(pg, jnp.float32, "const:8.0",
                                 (None, None,
                                  out_axis if pg[2] == c_out else None))
        specs["s_a"] = ParamSpec((1,), jnp.float32, "ones", (None,))
    return specs


def apply_conv(
    params: Dict,
    x: jnp.ndarray,
    cim: Optional["CIMConfig"] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    compute_dtype=jnp.bfloat16,
    variation_key: Optional[jax.Array] = None,
    variation_std=None,
) -> jnp.ndarray:
    """Conv dispatch: plain XLA conv without CIM, else the CIM framework
    (emulate grouped conv / fused Pallas deploy kernel). The variation
    knobs evaluate one Monte-Carlo cell-noise realization; emulate and
    deploy agree bit-exactly under a shared key (DESIGN.md §8)."""
    if cim is None or not cim.enabled:
        return jax.lax.conv_general_dilated(
            x.astype(compute_dtype), params["w"].astype(compute_dtype),
            (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    from repro.api import conv2d
    return conv2d(x, params, cim, stride=stride, padding=padding,
                  variation_key=variation_key,
                  variation_std=variation_std,
                  compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = dim or cfg.d_model
    if cfg.norm == "nonparam_ln":          # olmo: no learnable affine
        return {}
    return {"scale": ParamSpec((d,), jnp.float32, "ones", ("embed",))}


def apply_norm(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" or cfg.norm == "nonparam_ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    else:                                   # rmsnorm
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    if "scale" in p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def head_norm_specs(cfg: ModelConfig, hd: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((hd,), jnp.float32, "ones", (None,))}


def apply_head_rmsnorm(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core (full + KV-chunked flash-style)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)
                            ).reshape(b, t, h * n_rep, d)


def attention(
    q: jnp.ndarray,              # (B, Tq, H, hd)
    k: jnp.ndarray,              # (B, Tk, KvH, hd)
    v: jnp.ndarray,              # (B, Tk, KvH, hdv)
    *,
    causal: bool,
    q_offset: int | jnp.ndarray = 0,
    kv_len: Optional[jnp.ndarray] = None,   # valid KV length (decode)
    chunk: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Softmax attention; online-softmax scan over KV chunks when
    ``chunk`` is set and Tk > chunk (bounded memory for 32k prefill)."""
    b, tq, h, hd = q.shape
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    sc = scale if scale is not None else (1.0 / jnp.sqrt(hd).astype(jnp.float32))
    tk = k.shape[1]

    if not chunk or tk <= chunk:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * sc
        mask = _build_mask(tq, tk, causal, q_offset, kv_len)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)

    # --- chunked online softmax -------------------------------------------
    n_chunks = (tk + chunk - 1) // chunk
    pad = n_chunks * chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, v.shape[-1]).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc, c_idx = carry
        kb, vb = inp                                   # (B, C, H, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                       preferred_element_type=jnp.float32) * sc
        kpos = c_idx * chunk + jnp.arange(chunk)
        valid = kpos < tk
        if kv_len is not None:
            valid = valid[None, :] & (kpos[None, :] < kv_len[:, None])
            valid = valid[:, None, None, :]
        else:
            valid = valid[None, None, None, :]
        if causal:
            qpos = _qpos(q_offset, tq)                        # (B|1, tq)
            cmask = (qpos[:, :, None] >= kpos[None, None, :])[:, None]
            valid = valid & cmask
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new, c_idx + 1), None

    m0 = jnp.full((b, h, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    a0 = jnp.zeros((b, h, tq, v.shape[-1]), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)       # (B, Tq, H, hdv)


def _qpos(q_offset, tq):
    """(B, tq) or (1, tq) query positions from scalar or (B,) offset."""
    off = jnp.asarray(q_offset)
    if off.ndim == 0:
        off = off[None]
    return off[:, None] + jnp.arange(tq)[None, :]


def _build_mask(tq, tk, causal, q_offset, kv_len):
    parts = []
    kpos = jnp.arange(tk)
    if causal:
        qpos = _qpos(q_offset, tq)                            # (B|1, tq)
        parts.append((qpos[:, :, None] >= kpos[None, None, :])[:, None])
    if kv_len is not None:
        parts.append((kpos[None, :] < kv_len[:, None])[:, None, None, :])
    if not parts:
        return None
    mask = parts[0]
    for p in parts[1:]:
        mask = mask & p
    return mask


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = pdt(cfg)
    sp = {
        "wq": linear_specs(d, h * hd, cim=cfg.cim, in_axis="embed",
                           out_axis="heads", dtype=dt),
        "wk": linear_specs(d, kvh * hd, cim=cfg.cim, in_axis="embed",
                           out_axis="heads", dtype=dt),
        "wv": linear_specs(d, kvh * hd, cim=cfg.cim, in_axis="embed",
                           out_axis="heads", dtype=dt),
        "wo": linear_specs(h * hd, d, cim=cfg.cim, in_axis="heads",
                           out_axis="embed", dtype=dt),
    }
    if cfg.qk_norm:
        sp["q_norm"] = head_norm_specs(cfg, hd)
        sp["k_norm"] = head_norm_specs(cfg, hd)
    return sp


def gqa_attend(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,        # {"k","v","len"} decode cache
    causal: bool = True,
    x_kv: Optional[jnp.ndarray] = None,  # cross-attention source
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    b, t, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    src = x if x_kv is None else x_kv
    q = apply_linear(p["wq"], x, cfg.cim, compute_dtype=cdt(cfg)
                     ).reshape(b, t, h, hd)
    k = apply_linear(p["wk"], src, cfg.cim, compute_dtype=cdt(cfg)
                     ).reshape(b, src.shape[1], kvh, hd)
    v = apply_linear(p["wv"], src, cfg.cim, compute_dtype=cdt(cfg)
                     ).reshape(b, src.shape[1], kvh, hd)
    if cfg.qk_norm:
        q = apply_head_rmsnorm(p["q_norm"], q)
        k = apply_head_rmsnorm(p["k_norm"], k)
    if x_kv is None and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if cache is None else positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and x_kv is None:
        idx = cache["len"]                                   # (B,) int32
        kv8 = "k_scale" in cache                             # int8 KV cache
        ep = _flash_decode_ep_ready(cfg, t, cache["k"].shape[1],
                                    cache["k"].shape[0])
        if kv8:
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
        if ep is not None and not kv8:
            # sequence-parallel flash decode: cache stays time-sharded on
            # 'model'; each shard attends over its slice, partials merge
            # with one tiny psum (no per-layer cache all-gathers)
            out, kc, vc = _flash_decode_ep(q, k, v, cache["k"], cache["v"],
                                           idx, cfg, ep)
            new_cache = {"k": kc, "v": vc, "len": idx + t}
        elif ep is not None and kv8:
            out, kc, vc, ksc, vsc = _flash_decode_ep(
                q, kq, vq, cache["k"], cache["v"], idx, cfg, ep,
                k_scale_new=ks, v_scale_new=vs,
                k_scale=cache["k_scale"], v_scale=cache["v_scale"])
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc,
                         "len": idx + t}
        else:
            # write new K/V at position len, attend over the prefix
            def dus3(c, n, i):
                return jax.lax.dynamic_update_slice(c, n, (i, 0, 0))
            if kv8:
                kc = jax.vmap(dus3)(cache["k"], kq, idx)
                vc = jax.vmap(dus3)(cache["v"], vq, idx)
                ksc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
                    c, n, (i, 0)))(cache["k_scale"], ks, idx)
                vsc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
                    c, n, (i, 0)))(cache["v_scale"], vs, idx)
                new_cache = {"k": kc, "v": vc, "k_scale": ksc,
                             "v_scale": vsc, "len": idx + t}
                k_at = (kc.astype(jnp.float32)
                        * ksc[..., None]).astype(k.dtype)
                v_at = (vc.astype(jnp.float32)
                        * vsc[..., None]).astype(v.dtype)
            else:
                kc = jax.vmap(dus3)(cache["k"], k, idx)
                vc = jax.vmap(dus3)(cache["v"], v, idx)
                new_cache = {"k": kc, "v": vc, "len": idx + t}
                k_at, v_at = kc, vc
            out = attention(q, k_at, v_at, causal=True, q_offset=idx,
                            kv_len=idx + t, chunk=cfg.attn_chunk)
    else:
        out = attention(q, k, v, causal=causal and x_kv is None,
                        chunk=cfg.attn_chunk)
    y = apply_linear(p["wo"], out.reshape(b, t, h * hd), cfg.cim,
                     compute_dtype=cdt(cfg))
    return y, new_cache


def _kv_quantize(x: jnp.ndarray):
    """Per-(token, head) symmetric int8 quantization of K/V rows — the
    paper's column-wise-scale idea applied to the decode cache (each
    head-row gets its own scale, so heterogeneous heads survive 8 bits).
    x: (B, T, KvH, hd) -> (int8 codes, (B, T, KvH) scales)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s


# --- sequence-parallel flash decode (shard_map over the KV time shards) ----

def _flash_decode_ep_ready(cfg: ModelConfig, t: int, t_cache: int,
                           b: int = 0):
    """Returns the mesh when the EP flash-decode path applies: single new
    token, a production mesh in scope, cache time/batch dims divisible."""
    from repro.nn.module import current_mesh
    mesh = current_mesh()
    if (mesh is None or t != 1 or "model" not in mesh.axis_names
            or not cfg.flash_decode
            or t_cache % mesh.shape["model"] != 0):
        return None
    nb = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            nb *= mesh.shape[a]
    if b and b % nb != 0:
        return None
    return mesh


def _flash_decode_ep(q, k_new, v_new, kc, vc, idx, cfg: ModelConfig, mesh,
                     k_scale_new=None, v_scale_new=None,
                     k_scale=None, v_scale=None):
    """q: (B,1,H,hd); k_new/v_new: (B,1,KvH,hd) (int8 codes when scales are
    given); kc/vc: (B,T,KvH,hd) time-sharded over 'model'; idx: (B,).
    Returns (out, kc, vc[, k_scale, v_scale])."""
    from jax.sharding import PartitionSpec as P
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b, _, h, hd = q.shape
    kvh = k_new.shape[2]
    n_rep = h // kvh
    t_total = kc.shape[1]
    t_loc = t_total // mesh.shape["model"]
    sc = 1.0 / jnp.sqrt(float(hd))
    kv8 = k_scale is not None

    def local(qb, kn, vn, kcb, vcb, ib, ksn, vsn, ksb, vsb):
        my = jax.lax.axis_index("model")
        t0 = my * t_loc
        li = ib - t0                                          # (B,)
        write = (li >= 0) & (li < t_loc)
        safe = jnp.clip(li, 0, t_loc - 1)

        def upd(c, n, i, w):
            updated = jax.lax.dynamic_update_slice(c, n, (i, 0, 0))
            return jnp.where(w, updated, c)
        kcb = jax.vmap(upd)(kcb, kn, safe, write)
        vcb = jax.vmap(upd)(vcb, vn, safe, write)
        if kv8:
            def upd2(c, n, i, w):
                updated = jax.lax.dynamic_update_slice(c, n, (i, 0))
                return jnp.where(w, updated, c)
            ksb = jax.vmap(upd2)(ksb, ksn, safe, write)
            vsb = jax.vmap(upd2)(vsb, vsn, safe, write)
            k_at = (kcb.astype(jnp.float32) * ksb[..., None]).astype(qb.dtype)
            v_at = (vcb.astype(jnp.float32) * vsb[..., None]).astype(qb.dtype)
        else:
            k_at, v_at = kcb, vcb

        kk = _repeat_kv(k_at, n_rep)                          # (B,Tl,H,hd)
        vv = _repeat_kv(v_at, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kk,
                       preferred_element_type=jnp.float32) * sc
        kpos = t0 + jnp.arange(t_loc)
        valid = kpos[None, :] < (ib + 1)[:, None]             # (B,Tl)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                           # (B,H,1)
        m_g = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_g[..., None])
        l_loc = jnp.sum(p, axis=-1)
        acc_loc = jnp.einsum("bhqk,bkhd->bhqd", p.astype(jnp.float32),
                             vv.astype(jnp.float32))
        l_g = jax.lax.psum(l_loc, "model")
        acc_g = jax.lax.psum(acc_loc, "model")
        out = (acc_g / jnp.maximum(l_g[..., None], 1e-30))    # (B,H,1,hd)
        out = out.transpose(0, 2, 1, 3).astype(qb.dtype)
        return out, kcb, vcb, ksb, vsb

    if kv8:
        out, kc2, vc2, ks2, vs2 = shard_map(
            local, mesh=mesh,
            in_specs=(P(batch), P(batch), P(batch),
                      P(batch, "model"), P(batch, "model"), P(batch),
                      P(batch), P(batch), P(batch, "model"),
                      P(batch, "model")),
            out_specs=(P(batch), P(batch, "model"), P(batch, "model"),
                       P(batch, "model"), P(batch, "model")),
            check_vma=False,
        )(q, k_new, v_new, kc, vc, idx, k_scale_new, v_scale_new,
          k_scale, v_scale)
        return out, kc2, vc2, ks2, vs2

    def local_bf16(qb, kn, vn, kcb, vcb, ib):
        o, kcb2, vcb2, _, _ = local(qb, kn, vn, kcb, vcb, ib,
                                    None, None, None, None)
        return o, kcb2, vcb2

    out, kc2, vc2 = shard_map(
        local_bf16, mesh=mesh,
        in_specs=(P(batch), P(batch), P(batch),
                  P(batch, "model"), P(batch, "model"), P(batch)),
        out_specs=(P(batch), P(batch, "model"), P(batch, "model")),
        check_vma=False,
    )(q, k_new, v_new, kc, vc, idx)
    return out, kc2, vc2


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3): low-rank Q/KV compression, small decode cache
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = pdt(cfg)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": linear_specs(d, m.q_lora_rank, cim=cfg.cim, in_axis="embed",
                             out_axis=None, dtype=dt),
        "q_a_norm": {"scale": ParamSpec((m.q_lora_rank,), jnp.float32, "ones", (None,))},
        "wq_b": linear_specs(m.q_lora_rank, h * qk_dim, cim=cfg.cim,
                             in_axis=None, out_axis="heads", dtype=dt),
        "wkv_a": linear_specs(d, m.kv_lora_rank + m.qk_rope_dim, cim=cfg.cim,
                              in_axis="embed", out_axis=None, dtype=dt),
        "kv_a_norm": {"scale": ParamSpec((m.kv_lora_rank,), jnp.float32, "ones", (None,))},
        "wkv_b": linear_specs(m.kv_lora_rank, h * (m.qk_nope_dim + m.v_head_dim),
                              cim=cfg.cim, in_axis=None, out_axis="heads", dtype=dt),
        "wo": linear_specs(h * m.v_head_dim, d, cim=cfg.cim, in_axis="heads",
                           out_axis="embed", dtype=dt),
    }


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def mla_attend(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,   # {"ckv","krope","len"}
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim

    q = apply_linear(p["wq_b"],
                     _rms(apply_linear(p["wq_a"], x, cfg.cim, compute_dtype=cdt(cfg)),
                          p["q_a_norm"]["scale"]),
                     cfg.cim, compute_dtype=cdt(cfg)).reshape(b, t, h, qk_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = apply_linear(p["wkv_a"], x, cfg.cim, compute_dtype=cdt(cfg))
    ckv, k_rope = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    ckv = _rms(ckv, p["kv_a_norm"]["scale"])
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,T,1,r)

    new_cache = None
    if cache is not None:
        idx = cache["len"]
        ckv_c = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n, (i, 0)))(cache["ckv"], ckv, idx)
        kr_c = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n, (i, 0, 0)))(cache["krope"], k_rope, idx)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "len": idx + t}
        ckv_full, k_rope_full, kv_len = ckv_c, kr_c, idx + t
    else:
        ckv_full, k_rope_full, kv_len = ckv, k_rope, None

    kv = apply_linear(p["wkv_b"], ckv_full, cfg.cim, compute_dtype=cdt(cfg)
                      ).reshape(b, ckv_full.shape[1], h,
                                m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_full,
                                  k_nope.shape[:3] + (m.qk_rope_dim,))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(qq, k, v, causal=True,
                    q_offset=(cache["len"] if cache is not None else 0),
                    kv_len=kv_len, chunk=cfg.attn_chunk,
                    scale=1.0 / jnp.sqrt(float(qk_dim)))
    y = apply_linear(p["wo"], out.reshape(b, t, h * m.v_head_dim), cfg.cim,
                     compute_dtype=cdt(cfg))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = pdt(cfg)
    if cfg.act == "swiglu":
        return {
            "wg": linear_specs(d, f, cim=cfg.cim, in_axis="embed", out_axis="mlp", dtype=dt),
            "wu": linear_specs(d, f, cim=cfg.cim, in_axis="embed", out_axis="mlp", dtype=dt),
            "wd": linear_specs(f, d, cim=cfg.cim, in_axis="mlp", out_axis="embed", dtype=dt),
        }
    return {
        "wu": linear_specs(d, f, cim=cfg.cim, in_axis="embed", out_axis="mlp", dtype=dt),
        "wd": linear_specs(f, d, cim=cfg.cim, in_axis="mlp", out_axis="embed", dtype=dt),
    }


def apply_mlp(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.act == "swiglu":
        g = apply_linear(p["wg"], x, cfg.cim, compute_dtype=cdt(cfg))
        u = apply_linear(p["wu"], x, cfg.cim, compute_dtype=cdt(cfg))
        return apply_linear(p["wd"], jax.nn.silu(g) * u, cfg.cim,
                            compute_dtype=cdt(cfg))
    u = apply_linear(p["wu"], x, cfg.cim, compute_dtype=cdt(cfg))
    return apply_linear(p["wd"], jax.nn.gelu(u), cfg.cim, compute_dtype=cdt(cfg))


# ---------------------------------------------------------------------------
# Mixture-of-Experts with capacity-bounded sort-free dispatch
# ---------------------------------------------------------------------------
# Experts are sharded over the "experts"->model mesh axis. Dispatch packs
# each expert's tokens into a fixed-capacity buffer via scatter (dropped on
# overflow), runs all experts as one batched einsum, and scatter-adds the
# results back weighted by the router gates. HLO FLOPs are
# capacity_factor * active-expert FLOPs — not the dense n_experts/top_k
# blow-up — which keeps the roofline's useful-compute ratio honest.

def moe_specs(cfg: ModelConfig) -> Dict:
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff, mo.n_experts
    dt = pdt(cfg)
    sp = {
        "router": linear_specs(d, e, in_axis="embed", out_axis=None,
                               dtype=jnp.float32),
        "wg": ParamSpec((e, d, f), dt, "fan_in:1.0", ("experts", "embed", "mlp")),
        "wu": ParamSpec((e, d, f), dt, "fan_in:1.0", ("experts", "embed", "mlp")),
        "wd": ParamSpec((e, f, d), dt, "fan_in:1.0", ("experts", "mlp", "embed")),
    }
    if cfg.cim.enabled:
        t = cfg.cim.tiling(d, f)
        t2 = cfg.cim.tiling(f, d)
        for nm, tt, oax in (("wg", t, "mlp"), ("wu", t, "mlp"), ("wd", t2, "embed")):
            wg_s = tt.weight_scale_shape(cfg.cim.weight_granularity)
            pg_s = tt.psum_scale_shape(cfg.cim.psum_granularity)
            sp[f"{nm}_s_w"] = ParamSpec((e,) + wg_s, jnp.float32, "const:0.05",
                                        ("experts", None, oax if wg_s[1] == tt.n else None))
            sp[f"{nm}_s_p"] = ParamSpec((e,) + pg_s, jnp.float32, "const:8.0",
                                        ("experts", None, None, oax if pg_s[2] == tt.n else None))
            sp[f"{nm}_s_a"] = ParamSpec((e, 1), jnp.float32, "ones", ("experts", None))
    if mo.n_shared:
        sp["shared"] = mlp_specs(cfg, d_ff=mo.d_ff * mo.n_shared)
    return sp


#: largest packed expert bank (bytes) eligible for single-launch batched
#: dispatch — banks beyond this stream per expert via lax.map instead.
_EXPERT_BANK_BATCH_BYTES = 4 * 1024 * 1024


def _batched_experts_ok(p: Dict, nm: str, cfg: ModelConfig) -> bool:
    """Gate for the single-launch batched expert path: the plain deploy
    fast path only — kernel dispatch, unsharded mesh, saturation
    collector unarmed, unstacked (E-leading) bank that fits the VMEM
    streaming budget. Everything else keeps the proven lax.map."""
    from repro.kernels import ops as kops
    from repro.nn.module import current_mesh
    from repro.obs import adc as obs_adc
    d = p[f"{nm}_digits"]
    return (cfg.cim.mode == "deploy" and cfg.cim.use_kernel
            and getattr(d, "ndim", 0) == 5
            and not obs_adc.enabled()
            and kops.col_shards(current_mesh()) == 1
            and d.size * max(1, d.dtype.itemsize) <= _EXPERT_BANK_BATCH_BYTES)


def _batched_expert_matmul(p: Dict, nm: str, x: jnp.ndarray,
                           cfg: ModelConfig) -> jnp.ndarray:
    """All experts' capacity buffers through ONE kernel launch
    (kernels.ops.cim_matmul_experts; expert = leading grid dim) — the
    per-expert deploy prep (act codes, input tiling, fused dequant) is
    vmapped, mirroring core.cim_linear._forward_deploy per expert, and
    the kernel is bit-exact with lax.map of the per-expert kernel."""
    from repro.core.bitsplit import place_values
    from repro.core.cim_linear import (_full_psum_scale, _full_weight_scale,
                                       _tile_inputs, deploy_act_codes)
    from repro.kernels import ops as kops
    cim = cfg.cim
    digits = p[f"{nm}_digits"]
    t = cim.tiling(x.shape[-1], digits.shape[-1])
    places = place_values(cim.weight_bits, cim.cell_bits)

    def prep(xe, s_w, s_p, s_a):
        a_t = _tile_inputs(deploy_act_codes(xe, s_a, cim), t)
        pe = {"s_w": s_w, "s_p": s_p, "s_a": s_a}
        sp = _full_psum_scale(pe, t)
        deq = (places[:, None, None] * _full_weight_scale(pe, t)[None]
               * jnp.maximum(s_a, 1e-9))
        return a_t, sp, deq

    a_t, sp, deq = jax.vmap(prep)(x, p[f"{nm}_s_w"], p[f"{nm}_s_p"],
                                  p[f"{nm}_s_a"])
    y = kops.cim_matmul_experts(a_t, digits, sp, deq,
                                psum_bits=cim.psum_bits,
                                psum_quant=cim.psum_quant)
    return y.astype(cdt(cfg))


def _expert_matmul(p: Dict, nm: str, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (E, C, K) -> (E, C, N), optionally CIM-quantized per expert."""
    if not cfg.cim.enabled:
        return jnp.einsum("eck,ekn->ecn", x, p[nm].astype(cdt(cfg)),
                          preferred_element_type=cdt(cfg))
    from repro.api import linear
    from repro.api.backends import is_packed
    if is_packed(cfg.cim) and f"{nm}_digits" in p:
        # packed expert bank (pack_model): per-expert digit planes with
        # per-expert column scales, dispatched through the fused deploy
        # path. Small deploy banks take the single-launch batched kernel;
        # otherwise lax.map (scan) rather than vmap: pallas_call carries
        # no batching rule, and the column-sharded kernel wrapper is
        # already proven under scan by the stacked-layer serving path.
        if _batched_experts_ok(p, nm, cfg):
            return _batched_expert_matmul(p, nm, x, cfg)
        has_occ = f"{nm}_occ" in p   # v4 banks: per-expert occupancy maps
        def one(args):
            xe, d, s_w, s_p, s_a = args[:5]
            node = {"w_digits": d, "s_w": s_w, "s_p": s_p, "s_a": s_a}
            if has_occ:
                node["w_occ"] = args[5]
            return linear(xe, node, cfg.cim, compute_dtype=cdt(cfg))
        operands = (x, p[f"{nm}_digits"], p[f"{nm}_s_w"],
                    p[f"{nm}_s_p"], p[f"{nm}_s_a"])
        if has_occ:
            operands += (p[f"{nm}_occ"],)
        return jax.lax.map(one, operands)
    # unpacked tree on a packed backend: fall back to emulate (identical
    # quantization arithmetic; only the storage layout differs)
    ecfg = (cfg.cim if not is_packed(cfg.cim)
            else cfg.cim.replace(mode="emulate"))
    def one(xe, we, s_w, s_p, s_a):
        return linear(xe, {"w": we, "s_w": s_w, "s_p": s_p, "s_a": s_a},
                      ecfg, compute_dtype=cdt(cfg))
    return jax.vmap(one)(x, p[nm].astype(jnp.float32), p[f"{nm}_s_w"],
                         p[f"{nm}_s_p"], p[f"{nm}_s_a"])


def apply_moe(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dispatches to the shard_map expert-parallel path when lowering on a
    production mesh (experts sharded over 'model'); pure-jit fallback
    elsewhere (single device, tests)."""
    from repro.nn.module import current_mesh
    mesh = current_mesh()
    # packed expert banks (nm_digits planes) serve through the jit path:
    # their parallelism is column sharding inside the kernel wrapper
    # (DESIGN.md §10), not expert-parallel shard_map over raw banks
    packed_banks = any(k.endswith("_digits") for k in p)
    if (cfg.moe_impl != "jit" and not packed_banks and mesh is not None
            and "model" in mesh.axis_names
            and cfg.moe.n_experts % mesh.shape["model"] == 0):
        return _apply_moe_ep(p, x, cfg, mesh)
    return _apply_moe_jit(p, x, cfg)


def _apply_moe_jit(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    mo = cfg.moe
    b, t, d = x.shape
    n_tok = b * t
    e, k = mo.n_experts, mo.top_k
    xf = x.reshape(n_tok, d)

    logits = apply_linear(p["router"], xf.astype(jnp.float32), None,
                          compute_dtype=jnp.float32)          # (N, E)
    gates, sel = jax.lax.top_k(logits, k)                     # (N, k)
    gates = jax.nn.softmax(gates, axis=-1) if mo.router_scale else jax.nn.sigmoid(gates)

    # per-expert buffer slots. Every expert processes its full buffer, so
    # total expert FLOPs = e * cap * ffn — dropless (cap = n_tok*k) is only
    # affordable for tiny test workloads; production uses the capacity
    # factor (decode at B=128/E=256: 1.33x active FLOPs, not 64x).
    cap = int(mo.capacity_factor * n_tok * k / e) + 1
    if n_tok * k <= 256:
        cap = n_tok * k
    flat_e = sel.reshape(-1)                                  # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(n_tok), k)
    flat_g = gates.reshape(-1)

    # position of each (token, expert) pair within its expert's buffer
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    start = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(n_tok * k) - start[e_sorted]
    slot_sorted = jnp.where(pos_in_e < cap, e_sorted * cap + pos_in_e,
                            e * cap)                          # overflow -> dropped
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    slot = slot_sorted[inv]                                   # (N*k,)

    buf = jnp.zeros((e * cap + 1, d), cdt(cfg)).at[slot].set(
        xf.astype(cdt(cfg))[flat_tok], mode="drop")
    buf = buf[:-1].reshape(e, cap, d)
    buf = constrain(buf, ("experts", None, None))   # EP: experts on 'model'

    if cfg.act == "swiglu":
        g = _expert_matmul(p, "wg", buf, cfg)
        u = _expert_matmul(p, "wu", buf, cfg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(cdt(cfg)) * u
    else:
        h = jax.nn.gelu(_expert_matmul(p, "wu", buf, cfg).astype(jnp.float32)
                        ).astype(cdt(cfg))
    out_buf = _expert_matmul(p, "wd", h, cfg).reshape(e * cap, d)
    out_buf = constrain(out_buf, ("experts", None))
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], 0)

    y = jnp.zeros((n_tok, d), jnp.float32).at[flat_tok].add(
        out_buf[slot].astype(jnp.float32) * flat_g[:, None], mode="drop")
    y = constrain(y.astype(cdt(cfg)), ("batch", None))
    if mo.n_shared:
        y = y + apply_mlp(p["shared"], xf, cfg)
    return y.reshape(b, t, d)


# --- shard_map expert parallelism -------------------------------------------
# Key observation: at the MoE block the activations are replicated across
# the 'model' mesh axis (TP blocks psum before it) and sharded over the
# batch axes. Sharding experts over 'model' therefore needs NO all_to_all:
# every model-shard already holds the tokens, routes deterministically,
# gathers only the tokens its local experts own (capacity-bounded), runs
# its expert FFNs, scatter-adds its partial output, and ONE psum over
# 'model' merges expert partials — bytes per layer = activations, not the
# e*cap dispatch buffer the auto-SPMD path was replicating.

def _apply_moe_ep(p: Dict, x: jnp.ndarray, cfg: ModelConfig, mesh):
    from jax.sharding import PartitionSpec as P
    mo = cfg.moe
    b, t, d = x.shape
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ep = mesh.shape["model"]
    e_local = mo.n_experts // ep

    def local_moe(xf, router_w, wg, wu, wd, extra):
        # xf: (n_tok_local, d) — identical across the 'model' axis
        n_loc = xf.shape[0]
        k = mo.top_k
        logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))
        gates, sel = jax.lax.top_k(logits, k)                 # (n_loc, k)
        gates = (jax.nn.softmax(gates, axis=-1) if mo.router_scale
                 else jax.nn.sigmoid(gates))
        my = jax.lax.axis_index("model")
        lo = my * e_local
        cap = max(int(mo.capacity_factor * n_loc * k / mo.n_experts) + 1, 4)
        if n_loc * k <= 256:
            cap = n_loc * k

        flat_e = sel.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(n_loc), k)
        flat_g = gates.reshape(-1)
        mine = (flat_e >= lo) & (flat_e < lo + e_local)
        le = jnp.where(mine, flat_e - lo, e_local)            # local expert id
        order = jnp.argsort(le, stable=True)
        le_sorted = le[order]
        start = jnp.searchsorted(le_sorted, jnp.arange(e_local), side="left")
        pos = jnp.arange(n_loc * k) - start[jnp.clip(le_sorted, 0, e_local - 1)]
        slot_sorted = jnp.where(
            (le_sorted < e_local) & (pos < cap),
            le_sorted * cap + pos, e_local * cap)
        inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
        slot = slot_sorted[inv]

        buf = jnp.zeros((e_local * cap + 1, d), cdt(cfg)).at[slot].set(
            xf.astype(cdt(cfg))[flat_tok], mode="drop")
        buf = buf[:-1].reshape(e_local, cap, d)

        def mm(w, z, nm):
            if not cfg.cim.enabled:
                return jnp.einsum("eck,ekn->ecn", z, w.astype(cdt(cfg)),
                                  preferred_element_type=cdt(cfg))
            from repro.api import linear
            from repro.api.backends import is_packed
            ecfg = (cfg.cim if not is_packed(cfg.cim)
                    else cfg.cim.replace(mode="emulate"))
            s_w, s_p, s_a = (extra[f"{nm}_s_w"], extra[f"{nm}_s_p"],
                             extra[f"{nm}_s_a"])
            return jax.vmap(lambda ze, we, a_, b_, c_: linear(
                ze, {"w": we, "s_w": a_, "s_p": b_, "s_a": c_}, ecfg,
                compute_dtype=cdt(cfg)))(z, w.astype(jnp.float32), s_w,
                                         s_p, s_a)

        if cfg.act == "swiglu":
            h = jax.nn.silu(mm(wg, buf, "wg").astype(jnp.float32)
                            ).astype(cdt(cfg)) * mm(wu, buf, "wu")
        else:
            h = jax.nn.gelu(mm(wu, buf, "wu").astype(jnp.float32)
                            ).astype(cdt(cfg))
        out_buf = mm(wd, h, "wd").reshape(e_local * cap, d)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((1, d), out_buf.dtype)], 0)
        y = jnp.zeros((n_loc, d), jnp.float32).at[flat_tok].add(
            out_buf[slot].astype(jnp.float32) * flat_g[:, None], mode="drop")
        return jax.lax.psum(y.astype(jnp.float32), "model").astype(cdt(cfg))

    extra = {kk: p[kk] for kk in p
             if kk.startswith(("wg_", "wu_", "wd_"))} if cfg.cim.enabled else {}
    espec = {kk: P("model") for kk in extra}
    xf = x.reshape(b * t, d)
    y = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(batch, None), P(), P("model"), P("model"), P("model"),
                  espec),
        out_specs=P(batch, None),
        check_vma=False,
    )(xf, p["router"]["w"], p["wg"], p["wu"], p["wd"], extra)
    if mo.n_shared:
        y = y + apply_mlp(p["shared"], xf, cfg)
    return y.reshape(b, t, d)
