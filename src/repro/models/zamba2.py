"""Zamba2 hybrid (arXiv:2411.15242): a Mamba2 backbone with a *shared*
transformer block (one set of attention+MLP weights) invoked every
``attn_every`` SSM layers — the weight sharing is genuine: a single
parameter set applied at multiple depths, each application with its own
KV cache at decode time.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec, stack_specs
from .layers import (apply_mlp, apply_norm, cdt, gqa_attend, gqa_specs,
                     mlp_specs, norm_specs, pdt)
from .mamba2 import apply_mamba2, init_mamba_state, mamba2_specs


def _n_attn(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def specs(cfg: ModelConfig) -> Dict:
    sp: Dict = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), pdt(cfg), "normal:0.02",
                           ("vocab", "embed")),
        "ln_f": norm_specs(cfg),
        "mamba_layers": stack_specs(mamba2_specs(cfg), cfg.n_layers),
        "lm_head": linear_specs(cfg.d_model, cfg.vocab, in_axis="embed",
                                out_axis="vocab", dtype=pdt(cfg),
                                init="normal:0.02"),
    }
    if cfg.attn_every:
        sp["shared_attn"] = {                 # ONE weight set, reused
            "ln1": norm_specs(cfg),
            "attn": gqa_specs(cfg),
            "ln2": norm_specs(cfg),
            "mlp": mlp_specs(cfg),
        }
    return sp


def _shared_block(p, x, cfg, positions, cache):
    h, nc = gqa_attend(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                       positions=positions, cache=cache)
    x = x + h
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
    return x, nc


def _run(params, x, cfg: ModelConfig, positions, states):
    """Groups of ``attn_every`` scanned Mamba2 layers, shared attn between."""
    every = cfg.attn_every or cfg.n_layers
    n_groups = cfg.n_layers // every
    mam = partial(apply_mamba2, cfg=cfg)
    if cfg.remat:
        mam = jax.checkpoint(mam)
    new_mamba, new_attn = [], []
    for g in range(n_groups):
        sl = slice(g * every, (g + 1) * every)
        p_g = jax.tree.map(lambda a: a[sl], params["mamba_layers"])
        s_g = None if states is None else jax.tree.map(
            lambda a: a[sl], states["mamba"])

        if cfg.scan_layers:
            def body(carry, inp):
                p_i, st = inp
                y, ns = mam(p_i, carry, state=st)
                return y, ns
            x, ns = jax.lax.scan(body, x, (p_g, s_g))
        else:
            ns_list = []
            for i in range(every):
                p_i = jax.tree.map(lambda a: a[i], p_g)
                s_i = None if s_g is None else jax.tree.map(
                    lambda a: a[i], s_g)
                x, ns_i = mam(p_i, x, state=s_i)
                ns_list.append(ns_i)
            ns = (None if states is None
                  else jax.tree.map(lambda *xs: jnp.stack(xs), *ns_list))
        new_mamba.append(ns)
        if "shared_attn" in params:
            c_g = None if states is None else jax.tree.map(
                lambda a: a[g], states["attn"])
            blk = partial(_shared_block, cfg=cfg, positions=positions)
            if cfg.remat:
                blk = jax.checkpoint(blk)
            x, nc = blk(params["shared_attn"], x, cache=c_g)
            new_attn.append(nc)
    if states is None:
        return x, None
    return x, {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_mamba),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn),
    }


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
            extra_embeds=None) -> jnp.ndarray:
    x = params["embed"][tokens].astype(cdt(cfg))
    positions = jnp.arange(x.shape[1])
    x, _ = _run(params, x, cfg, positions, None)
    x = apply_norm(params["ln_f"], x, cfg)
    return apply_linear(params["lm_head"], x, None, compute_dtype=cdt(cfg))


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    st = init_mamba_state(cfg, batch)
    n_attn = _n_attn(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape
                                       ).copy(), st),
        "attn": {
            "k": jnp.zeros((n_attn, batch, max_len, kvh, hd), cdt(cfg)),
            "v": jnp.zeros((n_attn, batch, max_len, kvh, hd), cdt(cfg)),
            "len": jnp.zeros((n_attn, batch), jnp.int32),
        },
    }


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    x = params["embed"][tokens].astype(cdt(cfg))
    positions = cache["attn"]["len"][0][:, None] + jnp.arange(tokens.shape[1])[None]
    x, new_cache = _run(params, x, cfg, positions, cache)
    x = apply_norm(params["ln_f"], x, cfg)
    return apply_linear(params["lm_head"], x, None,
                        compute_dtype=cdt(cfg)), new_cache
