"""Mamba2 (SSD) blocks in JAX — chunked state-space-dual algorithm for
train/prefill (matmul-friendly, O(L) memory in chunks) and an O(1)-state
recurrent decode step. Used standalone and inside the zamba2 hybrid.

The SSD state update itself is an activation-activation op (no stored
weight) so it is not CIM-mapped (DESIGN.md §1); the in/out projections are
CIM-quantized linears like every other stored-weight matmul.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import apply_linear, linear_specs
from repro.nn.module import ParamSpec
from .layers import apply_norm, cdt, norm_specs, pdt


def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    n_groups = 1
    conv_dim = d_inner + 2 * n_groups * s.d_state
    return d_inner, n_heads, n_groups, conv_dim


def mamba2_specs(cfg: ModelConfig) -> Dict:
    s = cfg.ssm
    d_inner, nh, ng, conv_dim = mamba_dims(cfg)
    dt = pdt(cfg)
    in_dim = 2 * d_inner + 2 * ng * s.d_state + nh
    return {
        "ln": norm_specs(cfg),
        "in_proj": linear_specs(cfg.d_model, in_dim, cim=cfg.cim,
                                in_axis="embed", out_axis="mlp", dtype=dt),
        "conv_w": ParamSpec((s.d_conv, conv_dim), dt, "fan_in:1.0",
                            (None, "mlp")),
        "conv_b": ParamSpec((conv_dim,), jnp.float32, "zeros", ("mlp",)),
        "A_log": ParamSpec((nh,), jnp.float32,
                           lambda k, sh, d: jnp.log(jax.random.uniform(
                               k, sh, jnp.float32, 1.0, 16.0)), (None,)),
        "D": ParamSpec((nh,), jnp.float32, "ones", (None,)),
        "dt_bias": ParamSpec((nh,), jnp.float32,
                             lambda k, sh, d: jnp.log(jnp.exp(jax.random.uniform(
                                 k, sh, jnp.float32, 1e-3, 0.1)) - 1.0 + 1e-9),
                             (None,)),
        "out_norm": {"scale": ParamSpec((d_inner,), jnp.float32, "ones", ("mlp",))},
        "out_proj": linear_specs(d_inner, cfg.d_model, cim=cfg.cim,
                                 in_axis="mlp", out_axis="embed", dtype=dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. x: (B, L, C), w: (K, C). Returns (y, new
    state) where state is the last K-1 inputs for streaming decode."""
    k = w.shape[0]
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)             # (B, K-1+L, C)
    else:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xin[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    y = y + b[None, None, :].astype(y.dtype)
    new_state = xin[:, -(k - 1):, :] if k > 1 else xin[:, :0, :]
    return jax.nn.silu(y), new_state


def _segsum_decay(da_cs: jnp.ndarray) -> jnp.ndarray:
    """da_cs: (..., Q, H) within-chunk inclusive cumsum of dt*A.
    Returns lower-triangular decay matrix L: (..., H, Q, Q),
    L[i,j] = exp(cs_i - cs_j) for i >= j."""
    cs = jnp.swapaxes(da_cs, -1, -2)                          # (..., H, Q)
    diff = cs[..., :, None] - cs[..., None, :]                # (..., H, Q, Q)
    q = cs.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, D, chunk: int, initial_state=None):
    """Chunked SSD scan (Mamba2 alg. 1).

    x: (b, L, H, P); dt: (b, L, H); A: (H,); B, C: (b, L, G, N); D: (H,)
    initial_state: optional (b, H, N, P) carried state (stateful prefill).
    Returns y: (b, L, H, P) and the final state (b, H, N, P).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)                           # (b, L, H, N)
    Ch = jnp.repeat(C, rep, axis=2)
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = Bh.reshape(b, nc, chunk, H, N)
    Cc = Ch.reshape(b, nc, chunk, H, N)

    xdt = xc * dtc[..., None]                                 # fold dt into x
    da = dtc * A[None, None, None, :]                         # (b,nc,Q,H) <= 0
    da_cs = jnp.cumsum(da, axis=2)

    # intra-chunk (diagonal blocks)
    Ldec = _segsum_decay(da_cs)                               # (b,nc,H,Q,Q)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc) * Ldec
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xdt)

    # chunk-final states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)       # (b,nc,Q,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchnp", Bc, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # (b,nc,H)

    def body(S, inp):
        st, dec = inp                                         # (b,H,N,P),(b,H)
        S_new = S * dec[..., None, None] + st
        return S_new, S                                       # emit state BEFORE chunk

    S0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, H, N, P), jnp.float32))
    S_final, prev_states = jax.lax.scan(
        body, S0, (states.swapaxes(0, 1).astype(jnp.float32),
                   chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                  # (b,nc,H,N,P)

    state_decay_in = jnp.exp(da_cs)                           # (b,nc,Q,H)
    y_off = jnp.einsum("bclhn,bchnp,bclh->bclhp", Cc,
                       prev_states.astype(Cc.dtype), state_decay_in)

    y = (y_diag + y_off).reshape(b, Lp, H, P)[:, :L]
    y = y + x[:, :L] * D[None, None, :, None]
    return y, S_final


def apply_mamba2(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
                 state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """One Mamba2 block. state = {"conv": (B,K-1,convdim), "ssd": (B,H,N,P)}
    for streaming decode; None for train/prefill."""
    s = cfg.ssm
    d_inner, nh, ng, conv_dim = mamba_dims(cfg)
    bsz, L, _ = x.shape

    h = apply_norm(p["ln"], x, cfg)
    zxbcdt = apply_linear(p["in_proj"], h, cfg.cim, compute_dtype=cdt(cfg))
    z, xbc, dt_pre = jnp.split(
        zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(
        xbc.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
        p["conv_b"], conv_state)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + ng * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    A = -jnp.exp(p["A_log"])                                  # (H,) < 0
    xh = xs.reshape(bsz, L, nh, s.head_dim)
    Bm = B.reshape(bsz, L, ng, s.d_state)
    Cm = C.reshape(bsz, L, ng, s.d_state)

    if state is None:
        y, S = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], s.chunk)
        new_state = None
    elif L > 1:
        # stateful prefill: chunked scan from the carried state
        y, S = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], s.chunk,
                           initial_state=state["ssd"])
        new_state = {"conv": new_conv, "ssd": S}
    else:
        # single-step recurrence (L == 1)
        S = state["ssd"]                                      # (B,H,N,P)
        dt1 = dt[:, 0]                                        # (B,H)
        dec = jnp.exp(dt1 * A[None, :])
        Bx = jnp.einsum("bn,bhp->bhnp", Bm[:, 0, 0], xh[:, 0] * dt1[..., None])
        S = S * dec[..., None, None] + Bx
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0, 0], S) \
            + xh[:, 0] * p["D"][None, :, None]
        y = y[:, None]                                        # (B,1,H,P)
        new_state = {"conv": new_conv, "ssd": S}

    y = y.reshape(bsz, L, d_inner)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
    yf = yf * p["out_norm"]["scale"]
    out = apply_linear(p["out_proj"], yf.astype(cdt(cfg)), cfg.cim,
                       compute_dtype=cdt(cfg))
    if state is not None:
        return x + out, new_state
    return x + out, None


def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict:
    s = cfg.ssm
    d_inner, nh, ng, conv_dim = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.float32),
        "ssd": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
    }
