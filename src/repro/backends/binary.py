"""The ``binary`` hardware style: S=1 sign planes, multi-bit activations.

Binary-weight CIM (BWN-style, PAPERS.md) stores each weight as a single
±1 cell with a small per-group real scale α, while activations stay
multi-bit. In the packed digit-plane picture the whole bit-split axis
collapses: ``n_split = 1``, so a weight occupies ONE physical column
instead of ``ceil(weight_bits / cell_bits)`` — cells, arrays and ADC
conversions all drop ~n_split-fold (the cost model's style="binary"
tiling), and there is no shift-and-add stage (place value 2^0 alone).

Pack path (this module, resolved through ``Backend.pack_linear``/
``pack_conv`` via ``repro.api.backends.packers_for``):

* digits — ``sign(w)`` as a single (1, k_tiles, rows, N) plane (conv:
  (1, kt, kh, kw, cpa, C_out) in the stretched-kernel layout). Padded
  rows/channels store digit 0 (dead cells), exactly like the deploy pack.
* ``s_w`` — the BWN α, per (array-tile, column): mean |w| over the
  tile's real rows, stored at full column granularity (kt, N). The
  fused dequant is ``deq = α · s_a`` — same contract as deploy's
  ``2^{cs} · s_w · s_a`` with places = [1].
* ``s_p`` — full-shape (1, kt, N) ADC scales, initialized analytically
  (``_init_linear``'s magnitude model at cell_bits=1); refine with
  ``binary_calibrate_psum_scale`` on a data batch. The ADC stage itself
  is unchanged — binary arrays still digitize column psums at
  ``cfg.psum_bits`` — so the column-wise s_p story the paper tells
  applies to this style too.

The forward rides the UNCHANGED deploy machinery: ``kernels/ops``
dispatch (Pallas kernel / jnp oracle / column-sharded shard_map),
``perturb_packed`` variation on the S=1 planes, ``DeployArtifact``
round-trip and ``ScaleDelta`` recalibration (``deq_scale``) all work
as-is because only the plane geometry differs — which is what
``Backend.plane_bits = (1, 1)`` declares to spec builders.

Binarization is a real approximation (≈13% weight MSE for Gaussian
weights), so unlike adc_free this style trades accuracy for cost — the
point of charting all three on one frontier
(benchmarks/bench_backend_frontier.py).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.api.backends import (Backend, conv_plane_tiling, plane_tiling,
                                register_backend)
from repro.core.cim_linear import (CIMConfig, _tile_inputs, deploy_act_codes)
from repro.core.quantizer import qrange
from repro.core.variation import perturb_packed, variation_wanted


def _store_dtype(cfg: CIMConfig):
    # sign digits are {-1, 0, +1}: always fit int4 when requested
    return jnp.int4 if cfg.pack_dtype == "int4" else jnp.int8


def _analytic_s_p(t, cfg: CIMConfig, shape):
    """|P| ~ sqrt(rows)·E|a_int|·E|digit| with 1-bit cells (E|digit| ≈ 1/2
    of the 2^(cell_bits-1) digit range) — ``_init_linear``'s magnitude
    model evaluated at cell_bits=1."""
    _, qp_p = qrange(cfg.psum_bits, True)
    p_mag = jnp.sqrt(float(t.array_rows)) * (2 ** (cfg.act_bits - 2)) / 2.0
    return jnp.full(shape, 2.0 * p_mag / jnp.sqrt(float(max(qp_p, 1))),
                    jnp.float32)


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def pack_linear_binary(params: Dict[str, jnp.ndarray], cfg: CIMConfig, *,
                       variation_key: Optional[jax.Array] = None,
                       variation_std=None) -> Dict[str, jnp.ndarray]:
    """Binarize trained float params into the S=1 packed form.

    Consumes the same trainable quartet the deploy packer does ({w, s_w,
    s_p, s_a}); the multi-bit s_w/s_p are discarded — α and the binary
    s_p replace them (s_a carries over, so calibrate on emulate first)."""
    w = params["w"].astype(jnp.float32)
    k, n = w.shape
    t = plane_tiling(cfg, k, n)                       # weight_bits=cell_bits=1
    pad_k = t.k_padded - k
    sign = jnp.where(w >= 0, 1.0, -1.0)
    sign = jnp.pad(sign, ((0, pad_k), (0, 0)))        # dead rows: digit 0
    digits = sign.reshape(t.k_tiles, t.array_rows, n)[None]   # (1,kt,r,N)
    # BWN alpha per (array tile, column): mean |w| over the tile's REAL rows
    w_abs = jnp.abs(jnp.pad(w, ((0, pad_k), (0, 0))))
    w_t = w_abs.reshape(t.k_tiles, t.array_rows, n)
    rows = jnp.minimum(
        jnp.full((t.k_tiles,), t.array_rows),
        k - jnp.arange(t.k_tiles) * t.array_rows).astype(jnp.float32)
    alpha = w_t.sum(axis=1) / rows[:, None]           # (kt, n)
    out = {
        "w_digits": digits.astype(_store_dtype(cfg)),
        "s_w": alpha.astype(jnp.float32) + 1e-9,
        "s_p": _analytic_s_p(t, cfg, (1, t.k_tiles, n)),
        "s_a": params["s_a"],
        "k_logical": jnp.asarray(k, jnp.int32),
    }
    if variation_wanted(variation_key, variation_std):
        out = perturb_packed(out, variation_key, variation_std)
    return out


def pack_conv_binary(params: Dict[str, jnp.ndarray], cfg: CIMConfig, *,
                     variation_key: Optional[jax.Array] = None,
                     variation_std=None) -> Dict[str, jnp.ndarray]:
    """Binarize a trained HWIO conv into the S=1 stretched-kernel form
    (1, k_tiles, kh, kw, c_per_array, C_out) — layout-identical to the
    deploy conv pack at n_split=1, so the fused conv kernel, column
    sharding and 6-D variation noise consume it unchanged."""
    w = params["w"].astype(jnp.float32)
    kh, kw, c_in, c_out = w.shape
    t, cpa = conv_plane_tiling(cfg, kh, kw, c_in, c_out)
    c_pad = t.k_tiles * cpa - c_in
    sign = jnp.where(w >= 0, 1.0, -1.0)
    sign = jnp.pad(sign, ((0, 0), (0, 0), (0, c_pad), (0, 0)))
    d = sign.reshape(kh, kw, t.k_tiles, cpa, c_out)
    d = jnp.transpose(d, (2, 0, 1, 3, 4))[None]       # (1,kt,kh,kw,cpa,co)
    # alpha per (channel-slice array, column): mean |w| over the slice's
    # real channels x all taps
    w_abs = jnp.pad(jnp.abs(w), ((0, 0), (0, 0), (0, c_pad), (0, 0)))
    w_t = w_abs.reshape(kh, kw, t.k_tiles, cpa, c_out)
    ch = jnp.minimum(jnp.full((t.k_tiles,), cpa),
                     c_in - jnp.arange(t.k_tiles) * cpa).astype(jnp.float32)
    alpha = w_t.sum(axis=(0, 1, 3)) / (ch[:, None] * kh * kw)  # (kt, co)
    out = {
        "w_digits": d.astype(_store_dtype(cfg)),
        "s_w": alpha.astype(jnp.float32) + 1e-9,
        "s_p": _analytic_s_p(t, cfg, (1, t.k_tiles, c_out)),
        "s_a": params["s_a"],
    }
    if variation_wanted(variation_key, variation_std):
        out = perturb_packed(out, variation_key, variation_std)
    return out


def binary_calibrate_psum_scale(packed: Dict[str, jnp.ndarray],
                                cfg: CIMConfig,
                                x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Data-driven s_p refinement for a PACKED binary linear layer: the
    LSQ-style 2·E|P|/sqrt(q_p) init evaluated on the actual sign-plane
    psums of a calibration batch (the packed analogue of
    ``_calibrate_linear``, which needs trainable float params)."""
    digits = packed["w_digits"].astype(jnp.float32)   # (1, kt, rows, N)
    t = plane_tiling(cfg, int(x.shape[-1]), int(digits.shape[-1]))
    a_int = deploy_act_codes(x, packed["s_a"], cfg).astype(jnp.float32)
    a_t = _tile_inputs(a_int, t)
    flat = a_t.reshape((-1,) + a_t.shape[-2:])        # (B*, kt, rows)
    psum = jnp.einsum("mtr,strn->mstn", flat, digits,
                      preferred_element_type=jnp.float32)
    mean_abs = jnp.mean(jnp.abs(psum), axis=0)        # (1, kt, N)
    _, qp_p = qrange(cfg.psum_bits, True)
    s_p = (2.0 * mean_abs / jnp.sqrt(float(max(qp_p, 1)))
           ).astype(jnp.float32) + 1e-9
    return {**packed, "s_p": s_p}


# ---------------------------------------------------------------------------
# forwards
# ---------------------------------------------------------------------------

def _linear_binary(x, params, cfg, vkey, sigma, compute_dtype):
    from repro.kernels import ops as kops  # lazy: avoids import cycle
    from repro.nn.module import current_mesh

    digits = params["w_digits"]                       # (1, kt, rows, N)
    if not variation_wanted(vkey, sigma):
        vkey = sigma = None
    s_a = params["s_a"]
    a_int = deploy_act_codes(x, s_a, cfg)
    t = plane_tiling(cfg, x.shape[-1], digits.shape[-1])
    assert t.k_tiles == digits.shape[1] and t.array_rows == digits.shape[2], \
        (t.k_tiles, t.array_rows, digits.shape)
    a_t = _tile_inputs(a_int, t)

    s_p = t.broadcast_psum_scale(params["s_p"])       # (1, kt, N)
    alpha = t.broadcast_weight_scale(params["s_w"])   # (kt, N)
    deq = alpha[None] * jnp.maximum(s_a, 1e-9)        # place value 2^0 = 1
    if "deq_scale" in params:
        deq = deq * params["deq_scale"]

    y = kops.cim_matmul(
        a_t, digits, s_p, deq,
        psum_bits=cfg.psum_bits, psum_quant=cfg.psum_quant,
        use_kernel=cfg.use_kernel,
        variation_key=vkey, variation_std=sigma,
        mesh=current_mesh(),
    )
    return y.astype(compute_dtype)


def _conv_binary(x, params, cfg, stride, padding, vkey, sigma,
                 compute_dtype):
    from repro.kernels import ops as kops  # lazy: avoids import cycle
    from repro.nn.module import current_mesh

    d6 = params["w_digits"]              # (1, kt, kh, kw, cpa, C_out)
    s1, k_tiles, kh, kw, cpa, c_out = d6.shape
    digits = d6.reshape(s1, k_tiles, kh * kw * cpa, c_out)
    if not variation_wanted(vkey, sigma):
        vkey = sigma = None
    s_a = params["s_a"]
    a_int = deploy_act_codes(x, s_a, cfg)

    t, cpa2 = conv_plane_tiling(cfg, kh, kw, x.shape[-1], c_out)
    assert (t.k_tiles, cpa2) == (k_tiles, cpa), (
        f"packed binary conv planes {d6.shape} were built for a different "
        f"geometry than x/cfg imply: expected (k_tiles, c_per_array)="
        f"{(t.k_tiles, cpa2)}, packed {(k_tiles, cpa)}")

    s_p = t.broadcast_psum_scale(params["s_p"])       # (1, kt, co)
    alpha = t.broadcast_weight_scale(params["s_w"])   # (kt, co)
    deq = alpha[None] * jnp.maximum(s_a, 1e-9)
    if "deq_scale" in params:
        deq = deq * params["deq_scale"]

    y = kops.cim_conv(
        a_int, digits, s_p, deq,
        kh=kh, kw=kw, stride=stride, padding=padding,
        c_per_array=cpa,
        psum_bits=cfg.psum_bits, psum_quant=cfg.psum_quant,
        use_kernel=cfg.use_kernel,
        variation_key=vkey, variation_std=sigma,
        mesh=current_mesh(),
    )
    return y.astype(compute_dtype)


BINARY = Backend(
    name="binary",
    linear=_linear_binary,
    conv=_conv_binary,
    packed=True,
    description="binary-weight CIM: S=1 sign planes with per-column BWN "
                "alpha scales and multi-bit activations (n_split-fold "
                "fewer cells/arrays/conversions)",
    pack_linear=pack_linear_binary,
    pack_conv=pack_conv_binary,
    plane_bits=(1, 1))

register_backend(BINARY)
