"""Alternative CIM hardware styles as first-class backends (DESIGN.md §13).

Each module here registers one hardware style with the
``repro.api.backends`` registry at import time, making its name a valid
``CIMConfig.mode`` sharing the whole quantize→calibrate→pack→
``DeployArtifact``→serve lifecycle with the paper-faithful ``deploy``
style:

  adc_free  HCiM-style hybrid analog-digital CIM: bit-sliced partial
            sums leave the array exact and are accumulated digitally —
            no per-column ADC, no psum quantization error, ADC energy/
            area replaced by a digital accumulator in the cost model.
  binary    binary-weight (BWN-style) CIM: S=1 sign planes with a
            per-(array-tile, column) alpha scale and multi-bit
            activations — n_split collapses to 1, so cells, arrays and
            ADC conversions all drop ~n_split-fold.

This package is imported by ``repro.api.backends`` itself (bottom of the
module), so the styles are registered whenever the public API is — a
``CIMConfig(mode="adc_free")`` is constructible as soon as ``repro.api``
is imported. The frontier across all three styles is swept by
``benchmarks/bench_backend_frontier.py``.
"""
from __future__ import annotations

from .adc_free import ADC_FREE
from .binary import (BINARY, binary_calibrate_psum_scale, pack_conv_binary,
                     pack_linear_binary)

__all__ = [
    "ADC_FREE",
    "BINARY",
    "binary_calibrate_psum_scale",
    "pack_conv_binary",
    "pack_linear_binary",
]
