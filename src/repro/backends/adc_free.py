"""The ``adc_free`` hardware style: digital accumulation, no ADC.

HCiM-style hybrid analog-digital CIM (PAPERS.md) reads each bit-sliced
column MAC out of the array exactly and accumulates the partial sums in a
digital adder tree, so the per-(split, array, column) ADC — and with it
the psum quantization error the paper's column-wise s_p exists to tame —
disappears. What changes versus ``deploy``:

* **Arithmetic**: partial sums are never quantized; ``cfg.psum_bits`` /
  ``cfg.psum_quant`` / the packed ``s_p`` scales are carried but inert
  (s_p stays in the artifact so the same pack serves on either style).
  Numerically this backend equals ``emulate`` with ``psum_quant=False``
  and ``deploy`` whose ADC is transparent (s_p=1, wide psum_bits) —
  tests/test_backends.py pins both identities.
* **Kernel**: ``kernels/cim_adc_free.cim_matmul_adc_free_pallas`` — the
  deploy grid minus the VMEM ADC stage and minus the s_p operand stream.
* **Cost** (benchmarks/bench_hw_cost.layer_cost(style="adc_free")): the
  exponential-in-psum_bits ADC energy/area term is replaced by a linear
  digital-accumulator term at the full accumulation width
  ``act_bits + cell_bits + ceil(log2(rows))``.

Packing, artifact layout, column sharding and variation injection are
untouched: this style consumes the standard deploy pack (same
``w_digits``/``s_w``/``s_p``/``s_a`` tree), so one artifact serves on
``deploy``, ``ref`` *and* ``adc_free``, and emulate/deploy-grade
bit-exactness of `perturb_packed` noise carries over unchanged.
"""
from __future__ import annotations

from repro.api.backends import Backend, register_backend
from repro.core.cim_conv import _forward_conv_deploy
from repro.core.cim_linear import _forward_deploy


def _linear_adc_free(x, params, cfg, vkey, sigma, compute_dtype):
    return _forward_deploy(x, params, cfg, vkey, sigma, compute_dtype,
                           adc_free=True)


def _conv_adc_free(x, params, cfg, stride, padding, vkey, sigma,
                   compute_dtype):
    return _forward_conv_deploy(x, params, cfg, stride, padding, vkey,
                                sigma, compute_dtype, adc_free=True)


ADC_FREE = Backend(
    name="adc_free",
    linear=_linear_adc_free,
    conv=_conv_adc_free,
    packed=True,
    description="HCiM-style ADC-free CIM: exact digital accumulation of "
                "bit-sliced partial sums (no psum quantization); consumes "
                "the standard deploy pack")

register_backend(ADC_FREE)
