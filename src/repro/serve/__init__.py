from .engine import (ServingEngine, engine_from_artifact, make_decode_step,
                     make_prefill)

__all__ = ["ServingEngine", "engine_from_artifact", "make_decode_step",
           "make_prefill"]
