from .engine import ServingEngine, make_decode_step, make_prefill

__all__ = ["ServingEngine", "make_decode_step", "make_prefill"]
