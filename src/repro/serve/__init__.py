from .engine import (ServingEngine, engine_from_artifact, make_decode_step,
                     make_prefill)
from .health import DriftMonitor, HealthConfig, logit_stats, tap_stats

__all__ = ["DriftMonitor", "HealthConfig", "ServingEngine",
           "engine_from_artifact", "logit_stats", "make_decode_step",
           "make_prefill", "tap_stats"]
