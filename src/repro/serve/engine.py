"""Batched serving engine: slot-based continuous batching over the model
zoo's cache API.

Prefill runs the cached forward over the whole prompt (causal attention
with per-slot offsets, one pass); decode advances every active slot one
token per engine step. Finished slots are retired and refilled from the
queue without stalling the running batch — the standard continuous-
batching pattern, kept deliberately simple (fixed max_len slab per slot;
a paged KV allocator is an optimization, not a correctness need, and the
SSM families carry O(1) state anyway).

Self-healing serving (DESIGN.md §11): the engine optionally models a
drifting chip (``drift_key`` + ``drift_schedule``) — every decode step
serves one drift realization of the packed planes at the current request
count — watches its own logit statistics through a ``DriftMonitor``
(``health=``), degrades to the digital reference backend on hard drift,
and re-fits per-column scales in place via ``recalibrate()``.

Telemetry (DESIGN.md §12): every engine owns a ``repro.obs``
``MetricsRegistry`` (pass ``metrics=`` to share one). Request lifecycle
is traced — queue wait, prefill and per-decode-step spans land in the
registry's histograms and event log; token/request counters and queue
depth/active-slot gauges update as the slots churn. ``metrics()`` folds
all of it with ``health()``, derived throughput and — when the
``repro.obs.adc`` collector is armed — the ADC saturation summary into
one JSON-safe view; ``launch/serve.py --metrics-out`` writes exactly
that. When the collector is armed the monitor additionally ingests an
``adc_clip_rate`` statistic per step, so drift detection can trigger on
column clipping directly.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.variation import DriftSchedule, DriftState, drift_tree
from repro.models.registry import ModelFns
from repro.obs import adc as obs_adc
from repro.obs import names as M
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import Tracer


def engine_from_artifact(artifact, cfg: ModelConfig, *, mesh=None,
                         mesh_axis: str = "model",
                         **engine_kw) -> "ServingEngine":
    """Build a ``ServingEngine`` that serves a packed ``DeployArtifact``
    on its packed backend (the fused Pallas deploy path).

    ``artifact`` is a ``repro.api.DeployArtifact`` of kind "model" (or a
    path to one on disk); ``cfg`` is the architecture's ModelConfig — its
    ``cim`` field is replaced by the artifact's pinned deploy config, so
    the engine runs exactly the quantization state that was packed, and
    ``linear_specs``-style callers see a packed backend.

    ``mesh`` turns on column-parallel serving (DESIGN.md §10): every CIM
    layer's digit planes are placed column-sharded over ``mesh_axis`` as
    the artifact loads (each device receives only its own column slice),
    the mesh is installed as the session mesh (``set_activation_rules``)
    so the deploy forwards dispatch one kernel shard per device, and
    generation is bit-exact with the single-device engine serving the
    same artifact.

    The session mesh is process-global and stays installed after this
    call (a serving process serves one mesh for its lifetime);
    ``mesh=None`` does NOT clear a previously installed mesh. The engine
    records the mesh in scope at build time and **fails loudly** if a
    later ``step``/``generate_batch`` runs under a different one — its
    jitted functions trace against the build-time mesh, so silently
    inheriting another would serve wrong shardings. To mix sharded and
    unsharded engines in one process — tests, benchmarks — scope each
    engine's build *and* generation inside
    ``repro.nn.module.session_mesh(mesh)`` (or call
    ``set_activation_rules(None, None)`` to tear down).

    Drift/health keywords (``drift_key``, ``drift_schedule``, ``health``,
    ``auto_recalibrate``) pass through to ``ServingEngine``.
    """
    from repro.api import DeployArtifact
    from repro.models.registry import get_model
    if isinstance(artifact, (str, os.PathLike)):
        artifact = DeployArtifact.load(os.fspath(artifact), mesh=mesh,
                                       mesh_axis=mesh_axis)
    elif mesh is not None:
        artifact = artifact.shard(mesh, mesh_axis=mesh_axis)
    if artifact.kind != "model":
        raise ValueError(f"engine_from_artifact needs a 'model' artifact, "
                         f"got kind={artifact.kind!r}")
    if mesh is not None:
        from repro.nn.module import current_rules, set_activation_rules
        set_activation_rules(current_rules(), mesh)
    serve_cfg = dataclasses.replace(cfg, cim=artifact.config)
    model = get_model(serve_cfg)
    return ServingEngine(model, serve_cfg, artifact.params,
                         layout_version=artifact.layout_version, **engine_kw)


def make_prefill(model: ModelFns, cfg: ModelConfig):
    """(params, cache, tokens (B,T)) -> (logits (B,T,V), cache). Uses the
    decode path so caches fill in one pass."""
    def prefill(params, cache, tokens):
        return model.decode_step(params, cache, tokens, cfg)
    return jax.jit(prefill, donate_argnums=(1,))


def make_decode_step(model: ModelFns, cfg: ModelConfig,
                     temperature: float = 0.0):
    def step(params, cache, tokens, key):
        logits, cache = model.decode_step(params, cache, tokens, cfg)
        last = logits[:, -1, :].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache
    return jax.jit(step, donate_argnums=(1,))



def _make_engine_step(model: ModelFns, cfg: ModelConfig, temperature: float,
                     drift_key, schedule: Optional[DriftSchedule],
                     with_stats: bool):
    """Drift-aware decode step: injects one chip realization at request
    count ``t`` (a traced scalar — the clock advances with zero
    recompiles) and, when the health hook is armed, computes the logit
    statistics the monitor ingests inside the same jit."""
    drifting = (drift_key is not None and schedule is not None
                and not schedule.is_static_zero)

    def step(params, cache, tokens, key, t):
        p = params
        if drifting:
            p = drift_tree(params, drift_key, DriftState(schedule, t))
        logits, cache = model.decode_step(p, cache, tokens, cfg)
        last = logits[:, -1, :].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        stats = {}
        if with_stats:
            t2 = jax.lax.top_k(last, 2)[0]
            stats = {"logit_mean": jnp.mean(last),
                     "logit_var": jnp.var(last),
                     "logit_margin": jnp.mean(t2[:, 0] - t2[:, 1])}
        return nxt[:, None].astype(jnp.int32), cache, stats
    return jax.jit(step, donate_argnums=(1,))


def _make_engine_prefill(model: ModelFns, cfg: ModelConfig, drift_key,
                         schedule: Optional[DriftSchedule]):
    drifting = (drift_key is not None and schedule is not None
                and not schedule.is_static_zero)

    def prefill(params, cache, tokens, t):
        p = params
        if drifting:
            p = drift_tree(params, drift_key, DriftState(schedule, t))
        return model.decode_step(p, cache, tokens, cfg)
    return jax.jit(prefill, donate_argnums=(1,))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (T,) int32
    max_new_tokens: int
    eos_id: int = -1                     # -1: run to max_new_tokens
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0                # wall clock at submit()
    t_admit: float = 0.0                 # wall clock at slot admission


class ServingEngine:
    """Fixed-B slot engine. Prompts are prefilled one slot at a time (the
    cache API is batched, so we prefill with a masked batch); decode steps
    advance all live slots together.

    With ``drift_key``/``drift_schedule`` the engine serves a drifting
    chip: each decode step evaluates the packed planes under the drift
    field at the current request count ``t`` (one tick per model
    invocation). With ``health`` (a ``serve.health.DriftMonitor``) the
    engine observes its logit statistics every step; past the monitor's
    hard threshold it degrades to ``fallback_backend`` — the digital
    ``ref`` oracle on the *pristine* planes (digit storage does not
    drift; only the analog evaluation does) — until ``recalibrate()``
    lands a fresh ``ScaleDelta``, after which the corrected analog path
    serves again. ``auto_recalibrate=True`` closes the loop without an
    operator."""

    def __init__(self, model: ModelFns, cfg: ModelConfig, params,
                 batch_size: int = 8, max_len: int = 1024,
                 temperature: float = 0.0, seed: int = 0, *,
                 drift_key: Optional[jax.Array] = None,
                 drift_schedule: Optional[DriftSchedule] = None,
                 health=None,
                 fallback_backend: str = "ref",
                 auto_recalibrate: bool = False,
                 layout_version: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 report_every: int = 0):
        from repro.nn.module import current_mesh
        self.model, self.cfg, self.params = model, cfg, params
        self.B, self.max_len = batch_size, max_len
        self.mesh = current_mesh()          # pinned: see _check_mesh
        self.cache = model.init_cache(cfg, batch_size, max_len)
        self.temperature = temperature
        self.drift_key = drift_key
        self.drift_schedule = drift_schedule
        self.monitor = health
        self.fallback_backend = fallback_backend
        self.auto_recalibrate = auto_recalibrate
        self.layout_version = layout_version
        self.fallback_active = False
        self.t = 0                          # request-count drift clock
        self._pristine = params             # pre-recalibration reference
        self._fallback_step = None          # built lazily on first fallback
        with_stats = health is not None
        self._step_fn = _make_engine_step(model, cfg, temperature,
                                          drift_key, drift_schedule,
                                          with_stats)
        self._prefill_fn = _make_engine_prefill(model, cfg, drift_key,
                                                drift_schedule)
        self.decode = make_decode_step(model, cfg, temperature)
        self.key = jax.random.PRNGKey(seed)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.last_tok = np.zeros((batch_size, 1), np.int32)
        self._next_rid = 0
        self.retired = 0                    # requests completed, ever
        self.registry = metrics if metrics is not None else MetricsRegistry()
        self.tracer = Tracer(self.registry)
        self.report_every = report_every    # stderr line every N decode steps
        self._decode_steps = 0
        self._last_sat = 0                  # adc totals at last observation,
        self._last_conv = 0                 # for the per-step clip-rate delta

    def submit(self, prompt, max_new_tokens: int, eos_id: int = -1) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, t_submit=time.time())
        self.queue.append(req)
        self.registry.counter(M.REQUESTS_SUBMITTED).inc()
        self.registry.gauge(M.QUEUE_DEPTH).set(len(self.queue))
        self.registry.log_event("request_submitted", rid=rid,
                                prompt_len=int(req.prompt.shape[0]),
                                max_new_tokens=max_new_tokens)
        return rid

    # -- self-healing internals ----------------------------------------------

    def _check_mesh(self, where: str) -> None:
        """Fail loudly when generation runs under a different session
        mesh than the engine was built with — the jitted forwards traced
        against the build-time mesh, and silently inheriting another
        serves wrong shardings (the old ``mesh=None`` footgun)."""
        from repro.nn.module import current_mesh
        cur = current_mesh()
        if cur is self.mesh or cur == self.mesh:
            return
        raise RuntimeError(
            f"ServingEngine.{where}: the session mesh changed since this "
            f"engine was built (built under {self.mesh!r}, now {cur!r}). "
            "Rebuild the engine under the new mesh, or scope build and "
            "generation together in repro.nn.module.session_mesh(...).")

    def _invoke_step(self, tokens: jnp.ndarray, sub: jax.Array):
        """One model invocation: drift clock tick, fallback dispatch,
        health observation, optional auto-recalibration."""
        t = jnp.int32(self.t)
        self.t += 1
        if self.fallback_active:
            nxt, self.cache = self._fallback()(self.params_clean(),
                                               self.cache, tokens, sub)
            return nxt
        nxt, self.cache, stats = self._step_fn(self.params, self.cache,
                                               tokens, sub, t)
        self._observe_health(stats)
        return nxt

    def _observe_health(self, stats) -> None:
        """Feed one step's statistics to the drift monitor and react.
        When the ADC collector is armed, the folded saturation totals
        since the previous observation become an ``adc_clip_rate``
        statistic — the paper-native drift signal (DESIGN.md §12)."""
        if self.monitor is None or not stats:
            return
        host = {k: float(v) for k, v in stats.items()}
        if obs_adc.enabled():
            obs_adc.sync()
            sat, conv = obs_adc.totals()
            d_sat, d_conv = sat - self._last_sat, conv - self._last_conv
            self._last_sat, self._last_conv = sat, conv
            if d_conv > 0:
                host["adc_clip_rate"] = d_sat / d_conv
        self.monitor.observe(host)
        if self.monitor.hard_drifted and not self.fallback_active:
            self.monitor.hard_events += 1
            if self.auto_recalibrate:
                self.recalibrate()
            elif self.fallback_backend:
                self.fallback_active = True

    def params_clean(self):
        """The pristine packed tree (digit storage does not drift)."""
        return self._pristine

    def _fallback(self):
        if self._fallback_step is None:
            fcfg = dataclasses.replace(
                self.cfg, cim=self.cfg.cim.replace(mode=self.fallback_backend))
            self._fallback_step = make_decode_step(self.model, fcfg,
                                                   self.temperature)
        return self._fallback_step

    def recalibrate(self, *, probes: int = 64,
                    key: Optional[jax.Array] = None):
        """Re-fit per-column scales against the drift accumulated at the
        current request count and swap the corrected params in: fit a
        ``ScaleDelta`` from pristine planes to the drift realization at
        ``t`` (``eval/recalibrate.py``), apply it to the *pristine* tree
        (deltas are absolute), leave fallback, and re-arm the monitor.
        Returns the fitted delta (persist it with ``delta.save``)."""
        from repro.eval.recalibrate import (apply_scale_delta_params,
                                            fit_scale_delta)
        if key is None:
            self.key, key = jax.random.split(self.key)
        meta = {"t": int(self.t), "probes": probes}
        if (self.drift_key is not None and self.drift_schedule is not None
                and not self.drift_schedule.is_static_zero):
            observed = drift_tree(self._pristine, self.drift_key,
                                  DriftState(self.drift_schedule,
                                             jnp.int32(self.t)))
        else:
            observed = self._pristine   # no drift model: identity delta
        delta = fit_scale_delta(self._pristine, observed, key=key,
                                probes=probes, meta=meta)
        if self.layout_version is not None:
            delta = dataclasses.replace(delta,
                                        layout_version=self.layout_version)
        self.params = apply_scale_delta_params(self._pristine, delta)
        self.fallback_active = False
        if self.monitor is not None:
            self.monitor.note_recalibration()
        self.registry.counter(M.RECALIBRATIONS).inc()
        self.registry.log_event("recalibration", t=int(self.t), probes=probes)
        return delta

    def health(self) -> Dict:
        """Snapshot of the self-healing state: monitor counters (when a
        monitor is armed), the engine's own drift/fallback status, and
        the admission state — queue depth, active and retired slots."""
        snap = self.monitor.snapshot() if self.monitor is not None else {}
        snap.update({
            "t": self.t,
            "fallback_active": self.fallback_active,
            "drifting": (self.drift_key is not None
                         and self.drift_schedule is not None
                         and not self.drift_schedule.is_static_zero),
            "mesh": None if self.mesh is None else repr(self.mesh),
            "queue_depth": len(self.queue),
            "active_slots": sum(s is not None for s in self.slots),
            "slots": self.B,
            "submitted": self._next_rid,
            "retired": self.retired,
        })
        return snap

    def metrics(self) -> Dict:
        """One folded telemetry view (DESIGN.md §12): ``health()`` plus
        derived throughput, the ADC saturation summary (when the
        collector is armed) and the full registry snapshot. JSON-safe —
        ``launch/serve.py --metrics-out`` dumps it verbatim."""
        if obs_adc.enabled():
            obs_adc.sync()
        toks = self.registry.counter(M.TOKENS_GENERATED).value
        dec = self.registry.histogram(M.DECODE_STEP_SECONDS)
        tps = toks / dec.sum if dec.sum > 0 else 0.0
        n_dev = 1 if self.mesh is None else int(self.mesh.devices.size)
        return {
            "health": self.health(),
            "throughput": {
                "tokens_generated": toks,
                "decode_steps": dec.count,
                "decode_seconds": dec.sum,
                "tokens_per_sec": tps,
                "devices": n_dev,
                "tokens_per_sec_per_device": tps / n_dev,
            },
            "saturation": obs_adc.summary() if obs_adc.enabled() else None,
            "metrics": self.registry.snapshot(),
        }

    def _maybe_report(self) -> None:
        """Periodic one-line operator report on stderr (``report_every``
        decode steps; 0 = off)."""
        if not self.report_every:
            return
        if self._decode_steps % self.report_every:
            return
        toks = self.registry.counter(M.TOKENS_GENERATED).value
        dec = self.registry.histogram(M.DECODE_STEP_SECONDS)
        tps = toks / dec.sum if dec.sum > 0 else 0.0
        line = (f"[serve.metrics] t={self.t} tokens={toks} tok/s={tps:.1f} "
                f"queue={len(self.queue)} "
                f"active={sum(s is not None for s in self.slots)}/{self.B} "
                f"retired={self.retired}")
        if self.monitor is not None:
            line += (f" score={self.monitor.score:.2f}"
                     f" fallback={self.fallback_active}")
        if obs_adc.enabled():
            s = obs_adc.summary()
            line += f" clip_rate={s['clip_rate']:.4f}"
        print(line, file=sys.stderr)

    # -- internals -----------------------------------------------------------
    def _admit(self):
        """Fill empty slots: prefill the prompt token-by-token batched with
        zero-masked inactive slots (single-slot prefill keeps the engine
        simple; a bulk path would batch same-length prompts)."""
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                req.t_admit = time.time()
                self.registry.histogram(M.QUEUE_WAIT_SECONDS).observe(
                    req.t_admit - req.t_submit)
                with self.tracer.span("serve.prefill", rid=req.rid,
                                      tokens=int(req.prompt.shape[0])):
                    for t in req.prompt:
                        tok = np.array(self.last_tok)
                        tok[i, 0] = t
                        self.key, sub = jax.random.split(self.key)
                        nxt = self._invoke_step(jnp.asarray(tok), sub)
                        nxt = np.asarray(nxt)
                        # only slot i's cache row advanced meaningfully;
                        # other slots consumed a dummy token -> rewind
                        self.last_tok[i, 0] = nxt[i, 0]
                self.registry.gauge(M.QUEUE_DEPTH).set(len(self.queue))
                self.registry.gauge(M.ACTIVE_SLOTS).set(
                    sum(s is not None for s in self.slots))
        # NOTE: per-slot prefill advances other slots' caches too; engine
        # correctness relies on all slots being empty or synchronized. For
        # mixed workloads use `ServingEngine.generate_batch` (lockstep).

    def step(self) -> List[Dict]:
        """One decode step for all active slots; returns finished requests."""
        self._check_mesh("step")
        self._admit()
        if all(s is None for s in self.slots):
            return []
        self.key, sub = jax.random.split(self.key)
        with self.tracer.span("serve.decode.step"):
            nxt = np.asarray(self._invoke_step(jnp.asarray(self.last_tok),
                                               sub))
        self._decode_steps += 1
        active = sum(s is not None for s in self.slots)
        self.registry.counter(M.TOKENS_GENERATED).inc(active)
        finished = []
        now = time.time()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i, 0])
            req.output.append(tok)
            self.last_tok[i, 0] = tok
            if tok == req.eos_id or len(req.output) >= req.max_new_tokens:
                req.done = True
                finished.append({"rid": req.rid, "tokens": req.output})
                self.slots[i] = None
                self.retired += 1
                self.registry.counter(M.REQUESTS_COMPLETED).inc()
                self.registry.histogram(M.REQUEST_LATENCY_SECONDS).observe(
                    now - req.t_submit)
                self.registry.log_event(
                    "request_completed", rid=req.rid,
                    tokens=len(req.output),
                    latency=now - req.t_submit,
                    queue_wait=req.t_admit - req.t_submit)
        if finished:
            self.registry.gauge(M.ACTIVE_SLOTS).set(
                sum(s is not None for s in self.slots))
        self._maybe_report()
        return finished

    # -- the simple, correct batched API --------------------------------------
    def generate_batch(self, prompts: np.ndarray, max_new_tokens: int
                       ) -> np.ndarray:
        """Lockstep batched generation: prompts (B, Tp) -> (B, Tnew)."""
        self._check_mesh("generate_batch")
        assert prompts.shape[0] == self.B
        cache = self.model.init_cache(self.cfg, self.B, self.max_len)
        with self.tracer.span("serve.prefill", tokens=int(prompts.shape[1]),
                              batch=self.B):
            logits, cache = self._prefill_fn(self.params, cache,
                                             jnp.asarray(prompts),
                                             jnp.int32(self.t))
            self.t += 1
            tok = jnp.argmax(logits[:, -1:, :].astype(jnp.float32), axis=-1
                             ).astype(jnp.int32)
            outs = [np.asarray(tok)]
        self.registry.counter(M.TOKENS_GENERATED).inc(self.B)
        for _ in range(max_new_tokens - 1):
            self.key, sub = jax.random.split(self.key)
            t = jnp.int32(self.t)
            self.t += 1
            with self.tracer.span("serve.decode.step"):
                if self.fallback_active:
                    tok, cache = self._fallback()(self.params_clean(), cache,
                                                  tok, sub)
                    stats = {}
                else:
                    tok, cache, stats = self._step_fn(self.params, cache,
                                                      tok, sub, t)
                outs.append(np.asarray(tok))
            self._decode_steps += 1
            self.registry.counter(M.TOKENS_GENERATED).inc(self.B)
            self._observe_health(stats)
            self._maybe_report()
        return np.concatenate(outs, axis=1)
