"""Batched serving engine: slot-based continuous batching over the model
zoo's cache API.

Prefill runs the cached forward over the whole prompt (causal attention
with per-slot offsets, one pass); decode advances every active slot one
token per engine step. Finished slots are retired and refilled from the
queue without stalling the running batch — the standard continuous-
batching pattern, kept deliberately simple (fixed max_len slab per slot;
a paged KV allocator is an optimization, not a correctness need, and the
SSM families carry O(1) state anyway).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import ModelFns


def engine_from_artifact(artifact, cfg: ModelConfig, *, mesh=None,
                         mesh_axis: str = "model",
                         **engine_kw) -> "ServingEngine":
    """Build a ``ServingEngine`` that serves a packed ``DeployArtifact``
    on its packed backend (the fused Pallas deploy path).

    ``artifact`` is a ``repro.api.DeployArtifact`` of kind "model" (or a
    path to one on disk); ``cfg`` is the architecture's ModelConfig — its
    ``cim`` field is replaced by the artifact's pinned deploy config, so
    the engine runs exactly the quantization state that was packed, and
    ``linear_specs``-style callers see a packed backend.

    ``mesh`` turns on column-parallel serving (DESIGN.md §10): every CIM
    layer's digit planes are placed column-sharded over ``mesh_axis`` as
    the artifact loads (each device receives only its own column slice),
    the mesh is installed as the session mesh (``set_activation_rules``)
    so the deploy forwards dispatch one kernel shard per device, and
    generation is bit-exact with the single-device engine serving the
    same artifact.

    The session mesh is process-global and stays installed after this
    call (a serving process serves one mesh for its lifetime);
    ``mesh=None`` does NOT clear a previously installed mesh. To mix
    sharded and unsharded engines in one process — tests, benchmarks —
    scope each engine's build *and* generation inside
    ``repro.nn.module.session_mesh(mesh)`` (or call
    ``set_activation_rules(None, None)`` to tear down).
    """
    from repro.api import DeployArtifact
    from repro.models.registry import get_model
    if isinstance(artifact, (str, os.PathLike)):
        artifact = DeployArtifact.load(os.fspath(artifact), mesh=mesh,
                                       mesh_axis=mesh_axis)
    elif mesh is not None:
        artifact = artifact.shard(mesh, mesh_axis=mesh_axis)
    if artifact.kind != "model":
        raise ValueError(f"engine_from_artifact needs a 'model' artifact, "
                         f"got kind={artifact.kind!r}")
    if mesh is not None:
        from repro.nn.module import current_rules, set_activation_rules
        set_activation_rules(current_rules(), mesh)
    serve_cfg = dataclasses.replace(cfg, cim=artifact.config)
    model = get_model(serve_cfg)
    return ServingEngine(model, serve_cfg, artifact.params, **engine_kw)


def make_prefill(model: ModelFns, cfg: ModelConfig):
    """(params, cache, tokens (B,T)) -> (logits (B,T,V), cache). Uses the
    decode path so caches fill in one pass."""
    def prefill(params, cache, tokens):
        return model.decode_step(params, cache, tokens, cfg)
    return jax.jit(prefill, donate_argnums=(1,))


def make_decode_step(model: ModelFns, cfg: ModelConfig,
                     temperature: float = 0.0):
    def step(params, cache, tokens, key):
        logits, cache = model.decode_step(params, cache, tokens, cfg)
        last = logits[:, -1, :].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache
    return jax.jit(step, donate_argnums=(1,))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (T,) int32
    max_new_tokens: int
    eos_id: int = -1                     # -1: run to max_new_tokens
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Fixed-B slot engine. Prompts are prefilled one slot at a time (the
    cache API is batched, so we prefill with a masked batch); decode steps
    advance all live slots together."""

    def __init__(self, model: ModelFns, cfg: ModelConfig, params,
                 batch_size: int = 8, max_len: int = 1024,
                 temperature: float = 0.0, seed: int = 0):
        self.model, self.cfg, self.params = model, cfg, params
        self.B, self.max_len = batch_size, max_len
        self.cache = model.init_cache(cfg, batch_size, max_len)
        self.decode = make_decode_step(model, cfg, temperature)
        self.key = jax.random.PRNGKey(seed)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.last_tok = np.zeros((batch_size, 1), np.int32)
        self._next_rid = 0

    def submit(self, prompt, max_new_tokens: int, eos_id: int = -1) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id))
        return rid

    # -- internals -----------------------------------------------------------
    def _admit(self):
        """Fill empty slots: prefill the prompt token-by-token batched with
        zero-masked inactive slots (single-slot prefill keeps the engine
        simple; a bulk path would batch same-length prompts)."""
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                for t in req.prompt:
                    tok = np.array(self.last_tok)
                    tok[i, 0] = t
                    self.key, sub = jax.random.split(self.key)
                    nxt, self.cache = self.decode(self.params, self.cache,
                                                  jnp.asarray(tok), sub)
                    nxt = np.asarray(nxt)
                    # only slot i's cache row advanced meaningfully; other
                    # slots consumed a dummy token -> rewind their outputs
                    self.last_tok[i, 0] = nxt[i, 0]
        # NOTE: per-slot prefill advances other slots' caches too; engine
        # correctness relies on all slots being empty or synchronized. For
        # mixed workloads use `ServingEngine.generate_batch` (lockstep).

    def step(self) -> List[Dict]:
        """One decode step for all active slots; returns finished requests."""
        self._admit()
        if all(s is None for s in self.slots):
            return []
        self.key, sub = jax.random.split(self.key)
        nxt, self.cache = self.decode(self.params, self.cache,
                                      jnp.asarray(self.last_tok), sub)
        nxt = np.asarray(nxt)
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i, 0])
            req.output.append(tok)
            self.last_tok[i, 0] = tok
            if tok == req.eos_id or len(req.output) >= req.max_new_tokens:
                req.done = True
                finished.append({"rid": req.rid, "tokens": req.output})
                self.slots[i] = None
        return finished

    # -- the simple, correct batched API --------------------------------------
    def generate_batch(self, prompts: np.ndarray, max_new_tokens: int
                       ) -> np.ndarray:
        """Lockstep batched generation: prompts (B, Tp) -> (B, Tnew)."""
        assert prompts.shape[0] == self.B
        cache = self.model.init_cache(self.cfg, self.B, self.max_len)
        prefill = make_prefill(self.model, self.cfg)
        logits, cache = prefill(self.params, cache, jnp.asarray(prompts))
        tok = jnp.argmax(logits[:, -1:, :].astype(jnp.float32), axis=-1
                         ).astype(jnp.int32)
        outs = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            self.key, sub = jax.random.split(self.key)
            tok, cache = self.decode(self.params, cache, tok, sub)
            outs.append(np.asarray(tok))
        return np.concatenate(outs, axis=1)
