"""Online drift detection for the serving engine (DESIGN.md §11).

The monitor ingests per-step activation statistics — the same
``return_taps``-style summaries the models already expose (per-layer
activation mean/var) plus logit statistics (mean/var and the top-1/top-2
margin) — and maintains one exponentially-weighted moving average per
statistic. The first ``warmup`` observations *calibrate* the detector:
their mean and standard deviation define each statistic's healthy
baseline, so thresholds are in z-units of the serving workload's own
step-to-step variability rather than absolute magnitudes. After warmup
the drift score is

    score = max_k |ewma_k - mu_k| / max(sd_k, floor_k)

i.e. the worst standardized EWMA excursion across all tracked
statistics. ``soft_threshold`` marks detected drift (recalibration is
warranted); ``hard_threshold`` marks serving-quality danger — the engine
reacts by falling back to its digital reference backend until a
recalibration lands (serve/engine.py).

Hysteresis is explicit and deterministic. ``drifted``/``hard_drifted``
are gated on two conditions besides the score:

* **warmup**: every tracked statistic must have finished its baseline
  (``warmed_up``). A statistic mid-calibration has no meaningful z-score,
  so scores computed while any baseline is still forming never latch —
  including a statistic that first appears late (e.g. the ADC clip rate
  arriving only once sampling is armed).
* **post-recalibration grace**: ``note_recalibration()`` opens a
  deterministic grace window — the flags stay suppressed until
  ``hysteresis`` further observations have been folded; the
  ``hysteresis``-th observation after the recalibration is the first
  that can re-assert them. The EWMAs are re-seeded on the baseline at
  the same moment, so past the window the flags re-assert only if the
  *fresh* statistics still excurse — a recalibration that actually fixed
  the chip stays green, a cosmetic one goes red again ``hysteresis``
  observations later, always at the same step for the same input stream.

The monitor is plain host-side state: it never traces, never allocates
on device, and costs a handful of float ops per step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Detector knobs. ``ewma`` is the smoothing factor (weight of the
    newest observation); ``min_std_frac`` floors the baseline std at a
    fraction of the baseline mean's magnitude so deterministic
    statistics (greedy decode loops) don't divide by zero."""

    ewma: float = 0.25
    warmup: int = 8
    soft_threshold: float = 4.0     # z-units: drift detected, recalibrate
    hard_threshold: float = 12.0    # z-units: degrade, serve fallback
    min_std_frac: float = 0.02
    min_std_abs: float = 1e-6
    hysteresis: int = 4             # post-recal observations before re-latch

    def effective_warmup(self) -> int:
        """Baseline length actually used: ``warmup=0`` would leave a
        statistic with no baseline at all (mean/std of nothing), so the
        floor is one observation."""
        return max(1, self.warmup)


@dataclasses.dataclass
class _Stat:
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0                 # Welford accumulator over warmup
    ewma: Optional[float] = None

    def std(self) -> float:
        return math.sqrt(self.m2 / self.n) if self.n > 1 else 0.0


class DriftMonitor:
    """Running drift detector over a dict of scalar statistics."""

    def __init__(self, config: Optional[HealthConfig] = None):
        self.config = config or HealthConfig()
        self._stats: Dict[str, _Stat] = {}
        self.steps = 0
        self.score = 0.0
        self.drifted_at: Optional[int] = None   # step of first soft crossing
        self.hard_events = 0
        self.recalibrations = 0
        self._grace = 0             # post-recal observations still to skip

    # -- ingestion -----------------------------------------------------------

    def observe(self, stats: Mapping[str, float]) -> float:
        """Fold one step's statistics in; returns the current score."""
        cfg = self.config
        self.steps += 1
        if self._grace > 0:
            self._grace -= 1
        score = 0.0
        for name, value in stats.items():
            v = float(value)
            if not math.isfinite(v):
                continue
            st = self._stats.setdefault(name, _Stat())
            if st.n < cfg.effective_warmup():
                # calibration phase: accumulate the healthy baseline
                st.n += 1
                d = v - st.mean
                st.mean += d / st.n
                st.m2 += d * (v - st.mean)
                st.ewma = v if st.ewma is None else (
                    cfg.ewma * v + (1 - cfg.ewma) * st.ewma)
                continue
            st.ewma = cfg.ewma * v + (1 - cfg.ewma) * st.ewma
            floor = max(cfg.min_std_abs, cfg.min_std_frac * abs(st.mean))
            z = abs(st.ewma - st.mean) / max(st.std(), floor)
            score = max(score, z)
        self.score = score
        if self.drifted and self.drifted_at is None:
            self.drifted_at = self.steps
        return score

    def note_recalibration(self) -> None:
        """A recalibration landed: count it, re-seed the EWMAs on the
        baseline so the score relaxes immediately instead of waiting out
        the smoothing horizon (the drifted history is no longer serving
        reality), clear the latch, and open the ``hysteresis`` grace
        window (module docstring)."""
        self.recalibrations += 1
        for st in self._stats.values():
            if st.n > 0:
                st.ewma = st.mean
        self.score = 0.0
        self.drifted_at = None
        self._grace = self.config.hysteresis

    # -- queries -------------------------------------------------------------

    @property
    def warmed_up(self) -> bool:
        w = self.config.effective_warmup()
        return bool(self._stats) and all(
            s.n >= w for s in self._stats.values())

    @property
    def in_grace(self) -> bool:
        """Inside the post-recalibration hysteresis window."""
        return self._grace > 0

    @property
    def drifted(self) -> bool:
        return (self.warmed_up and not self.in_grace
                and self.score >= self.config.soft_threshold)

    @property
    def hard_drifted(self) -> bool:
        return (self.warmed_up and not self.in_grace
                and self.score >= self.config.hard_threshold)

    def snapshot(self) -> Dict[str, object]:
        """Counters + per-stat state for an engine ``health()`` call."""
        return {
            "steps": self.steps,
            "score": self.score,
            "drifted": self.drifted,
            "hard_drifted": self.hard_drifted,
            "drifted_at": self.drifted_at,
            "hard_events": self.hard_events,
            "recalibrations": self.recalibrations,
            "warmed_up": self.warmed_up,
            "grace": self._grace,
            "stats": {
                name: {"baseline_mean": st.mean, "baseline_std": st.std(),
                       "ewma": st.ewma, "n": st.n}
                for name, st in self._stats.items()
            },
        }


# ---------------------------------------------------------------------------
# statistic extractors (host-side, one float per entry)
# ---------------------------------------------------------------------------

def tap_stats(taps: Mapping[str, jnp.ndarray]) -> Dict[str, float]:
    """Per-layer activation mean/var from a ``return_taps`` dict."""
    out: Dict[str, float] = {}
    for name, a in taps.items():
        af = jnp.asarray(a, jnp.float32)
        out[f"{name}.mean"] = float(jnp.mean(af))
        out[f"{name}.var"] = float(jnp.var(af))
    return out


def logit_stats(logits) -> Dict[str, float]:
    """Mean/var and mean top-1/top-2 margin of a (..., V) logit batch —
    the margin collapses first under drift (wrong tokens start winning),
    which makes it the most sensitive single statistic."""
    lf = jnp.asarray(logits, jnp.float32).reshape(-1, logits.shape[-1])
    t2 = jax.lax.top_k(lf, 2)[0]
    return {
        "logit_mean": float(jnp.mean(lf)),
        "logit_var": float(jnp.var(lf)),
        "logit_margin": float(jnp.mean(t2[:, 0] - t2[:, 1])),
    }
