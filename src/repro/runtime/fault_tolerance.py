"""Fault-tolerant training loop: checkpoint/restart, failure injection,
elastic re-mesh.

Scaling notes for 1000+ nodes (what changes on a real fleet):
  * jax.distributed.initialize + a coordinator service own membership; a
    missing heartbeat marks the host dead, the coordinator drains the
    barrier and relaunches the SPMD program on the surviving slice (or a
    spare pod). This module's FaultTolerantLoop is the per-process part:
    always-resumable state, emergency save on signals, and restore that
    reshards onto whatever mesh the relaunch got (elastic).
  * checkpoints fan in hierarchically (per-host shards -> pod aggregators
    -> blob store) instead of this box's single-directory writes; the
    manifest/commit protocol is identical.
  * data pipeline state is (seed, step), so resumption is exact (see
    repro/data/pipeline.py) — no reader offsets to persist.

The failure-injection path (``crash_at_step``) is used by the integration
tests: train k steps, "crash", relaunch, verify the loss trajectory equals
an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from .straggler import StragglerMonitor


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainLoopState:
    params: Any
    opt_state: Any
    step: int
    extra: Optional[Dict] = None       # e.g. BN state, EF buffers


class FaultTolerantLoop:
    """Wraps (train_step, pipeline) with checkpoint/restore/emergency-save.

    train_step: (params, opt_state, batch) -> (params, opt_state, metrics)
    """

    def __init__(self, ckpt_dir: str, *, checkpoint_every: int = 100,
                 keep_n: int = 3, async_save: bool = True,
                 install_signal_handlers: bool = False):
        self.mgr = CheckpointManager(ckpt_dir, keep_n=keep_n,
                                     async_save=async_save)
        self.checkpoint_every = checkpoint_every
        self.straggler = StragglerMonitor()
        self._restart_requested = False
        self._state: Optional[TrainLoopState] = None
        if install_signal_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                signal.signal(sig, self._emergency)

    # -- coordinator hooks -----------------------------------------------------
    def request_restart(self, *_args):
        """Called by straggler policy / external watchdog."""
        self._restart_requested = True

    def _emergency(self, signum, frame):
        if self._state is not None:
            self.mgr.save(self._state.step, self._pack(self._state))
            self.mgr.wait()
        raise SystemExit(128 + signum)

    # -- (de)serialization ------------------------------------------------------
    @staticmethod
    def _pack(st: TrainLoopState) -> Dict:
        out = {"params": st.params, "opt_state": st.opt_state,
               "step": np.asarray(st.step, np.int64)}
        if st.extra is not None:
            out["extra"] = st.extra
        return out

    def resume_or_init(self, init_fn: Callable[[], TrainLoopState],
                       shardings: Any = None) -> TrainLoopState:
        """Restore the latest checkpoint if one exists (resharding onto the
        current mesh when shardings are given), else initialize fresh."""
        latest = self.mgr.latest_step()
        st = init_fn()
        if latest is None:
            return st
        like = self._pack(st)
        sh = None
        if shardings is not None:
            sh = {"params": shardings.get("params"),
                  "opt_state": shardings.get("opt_state"),
                  "step": None}
            if st.extra is not None:
                sh["extra"] = shardings.get("extra")
            sh = jax.tree.map(lambda _: None, like) if sh is None else sh
        restored = self.mgr.restore(like, step=latest, shardings=None)
        if shardings is not None:
            restored["params"] = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored["params"],
                shardings["params"])
            if "opt_state" in shardings and shardings["opt_state"] is not None:
                restored["opt_state"] = jax.tree.map(
                    lambda x, s: jax.device_put(x, s),
                    restored["opt_state"], shardings["opt_state"])
        return TrainLoopState(params=restored["params"],
                              opt_state=restored["opt_state"],
                              step=int(restored["step"]),
                              extra=restored.get("extra"))

    # -- the loop ----------------------------------------------------------------
    def run(self, state: TrainLoopState, train_step: Callable,
            batches: Iterator, *, total_steps: int,
            crash_at_step: Optional[int] = None,
            log_every: int = 10,
            on_metrics: Optional[Callable[[int, Dict], None]] = None
            ) -> TrainLoopState:
        self._state = state
        while state.step < total_steps:
            if crash_at_step is not None and state.step == crash_at_step:
                raise InjectedFailure(f"injected failure at step {state.step}")
            batch = next(batches)
            self.straggler.step_start()
            params, opt_state, metrics = train_step(
                state.params, state.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            verdict = self.straggler.step_end()
            state = TrainLoopState(params, opt_state, state.step + 1,
                                   state.extra)
            self._state = state
            if verdict == "critical":
                self.request_restart()
            if on_metrics and (state.step % log_every == 0):
                on_metrics(state.step, jax.tree.map(np.asarray, metrics))
            if state.step % self.checkpoint_every == 0:
                self.mgr.save(state.step, self._pack(state))
        self.mgr.save(state.step, self._pack(state))
        self.mgr.wait()
        return state
