"""Straggler detection & mitigation policy.

On a 1000+-node fleet the dominant tail-latency sources are (a) slow hosts
(thermal, ECC retry, flaky HBM), (b) input-pipeline stalls, (c) pre-empted
pods. Synchronous SPMD means the step time is the max over hosts, so the
policy below watches the *local* step-time distribution and classifies:

  WARN     step > warn_factor * rolling median   (log, count)
  CRITICAL step > crit_factor * rolling median   (report to coordinator;
           on real fleets the coordinator hot-swaps the host with a spare
           pod slice and the run restores from the latest checkpoint —
           wired to FaultTolerantLoop.request_restart)

The statistics (rolling median via a bounded reservoir) are unit-tested;
the hot-swap RPC is a no-op hook on this single-host box.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Deque, Optional


class StragglerMonitor:
    def __init__(self, window: int = 64, warn_factor: float = 1.5,
                 crit_factor: float = 3.0, min_samples: int = 8,
                 on_critical: Optional[Callable[[float, float], None]] = None):
        self.window: Deque[float] = deque(maxlen=window)
        self.warn_factor = warn_factor
        self.crit_factor = crit_factor
        self.min_samples = min_samples
        self.on_critical = on_critical
        self.n_warn = 0
        self.n_crit = 0
        self._t0: Optional[float] = None

    # -- timing API -----------------------------------------------------------
    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self) -> str:
        assert self._t0 is not None, "step_start not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt)

    # -- pure policy (unit-tested) ---------------------------------------------
    def median(self) -> float:
        s = sorted(self.window)
        n = len(s)
        if n == 0:
            return 0.0
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def observe(self, step_time: float) -> str:
        """Returns 'ok' | 'warn' | 'critical' and updates state."""
        verdict = "ok"
        if len(self.window) >= self.min_samples:
            med = self.median()
            if step_time > self.crit_factor * med:
                verdict = "critical"
                self.n_crit += 1
                if self.on_critical:
                    self.on_critical(step_time, med)
            elif step_time > self.warn_factor * med:
                verdict = "warn"
                self.n_warn += 1
        # stragglers do not poison the baseline: only 'ok' samples enter
        if verdict == "ok":
            self.window.append(step_time)
        return verdict
