from .fault_tolerance import FaultTolerantLoop, TrainLoopState
from .straggler import StragglerMonitor

__all__ = ["FaultTolerantLoop", "StragglerMonitor", "TrainLoopState"]
