"""Typed layer handles: one lifecycle for every CIM layer.

``QuantLinear`` and ``QuantConv2d`` wrap a ``CIMConfig`` plus a param
tree behind the uniform lifecycle

    handle = QuantLinear(k, n, cfg).init(key)   # trainable emulate params
    handle.calibrate(x)                         # one-batch s_a/s_p init
    y = handle(x, variation=Variation(key, s))  # forward on cfg's backend
    artifact = handle.pack()                    # versioned DeployArtifact
    served = QuantLinear.from_artifact(artifact)  # packed, deploy backend

so linear and conv stop being separate vocabularies (`init_cim_linear`
vs `init_cim_conv`, `calibrate_cim` vs `calibrate_cim_conv`, ...).
Handles are thin, mutable conveniences for scripts/examples/serving; QAT
training loops keep using the functional layer (``repro.api.linear`` /
``conv2d`` on explicit param trees) which jit/grad transform cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.cim_conv import _calibrate_conv, _conv_forward, _init_conv
from repro.core.cim_linear import (CIMConfig, _calibrate_linear, _init_linear,
                                   _linear_forward)

from .artifact import DeployArtifact, _packed_config
from .backends import packers_for


@dataclasses.dataclass(frozen=True)
class Variation:
    """One Monte-Carlo device realization: log-normal cell noise drawn
    from ``key`` with std ``std`` (may be a traced scalar; ``None`` falls
    back to ``cfg.variation_std``)."""
    key: Optional[jax.Array] = None
    std: Optional[object] = None


def _vkv(variation: Optional[Variation]):
    if variation is None:
        return None, None
    return variation.key, variation.std


class _Handle:
    """Shared lifecycle plumbing; subclasses bind the layer kind."""

    kind: str

    def __init__(self, cfg: CIMConfig,
                 params: Optional[Dict[str, jnp.ndarray]] = None):
        self.cfg = cfg
        self.params = params

    def _require_params(self, op: str):
        if self.params is None:
            raise ValueError(f"{type(self).__name__}.{op}: no params — "
                             "call .init(key) or .from_artifact(...) first")
        return self.params

    def _require_trainable(self, op: str):
        params = self._require_params(op)
        if "w" not in params:
            raise ValueError(
                f"{type(self).__name__}.{op}: params are packed digit "
                "planes (w_digits); this operation needs the trainable "
                "float weights — use the pre-pack handle or .init(key)")
        return params

    def with_backend(self, mode: str):
        """Same params, dispatched to another registered backend. The
        target backend must consume the params layout this handle holds
        (packed digit planes vs trainable weights) — mismatches fail here
        with a clear message, not as a KeyError mid-trace."""
        from .backends import get_backend
        target = get_backend(mode)   # unknown names fail loudly here too
        if self.params is not None and self.cfg.enabled:
            have_packed = "w_digits" in self.params
            if target.packed != have_packed:
                have = "packed digit planes" if have_packed \
                    else "trainable float weights"
                need = "packed digit planes" if target.packed \
                    else "trainable float weights"
                raise ValueError(
                    f"backend {mode!r} consumes {need}, but this "
                    f"{type(self).__name__} holds {have}; use .pack() / "
                    ".from_artifact(...) to convert")
        clone = type(self).__new__(type(self))
        clone.__dict__.update(self.__dict__)
        clone.cfg = self.cfg.replace(mode=mode)
        return clone


class QuantLinear(_Handle):
    """CIM linear layer handle: x (..., K) @ W (K, N) -> (..., N)."""

    kind = "linear"

    def __init__(self, k: int, n: int, cfg: CIMConfig, *,
                 params: Optional[Dict[str, jnp.ndarray]] = None):
        super().__init__(cfg, params)
        self.k, self.n = int(k), int(n)

    def init(self, key: jax.Array, *, w_init_scale: float | None = None,
             dtype=jnp.float32) -> "QuantLinear":
        self.params = _init_linear(key, self.k, self.n, self.cfg,
                                   w_init_scale, dtype)
        return self

    def calibrate(self, x: jnp.ndarray) -> "QuantLinear":
        self.params = _calibrate_linear(x, self._require_trainable("calibrate"),
                                        self.cfg)
        return self

    def __call__(self, x: jnp.ndarray, *,
                 variation: Optional[Variation] = None,
                 compute_dtype=jnp.float32) -> jnp.ndarray:
        vkey, vstd = _vkv(variation)
        return _linear_forward(x, self._require_params("__call__"), self.cfg,
                               variation_key=vkey, variation_std=vstd,
                               compute_dtype=compute_dtype)

    def pack(self, *, variation: Optional[Variation] = None,
             meta: Optional[Dict] = None) -> DeployArtifact:
        vkey, vstd = _vkv(variation)
        pack_lin, _ = packers_for(_packed_config(self.cfg))
        packed = pack_lin(self._require_trainable("pack"), self.cfg,
                          variation_key=vkey, variation_std=vstd)
        # col_shard: the planes' output-column (N) axis is the unit of
        # independence column-parallel serving shards over (DESIGN.md §10)
        m = {"k": self.k, "n": self.n, **(meta or {}),
             "col_shard": {"": -1}}
        return DeployArtifact(kind="linear", config=_packed_config(self.cfg),
                              params=packed, meta=m)

    @classmethod
    def from_artifact(cls, artifact: DeployArtifact) -> "QuantLinear":
        if artifact.kind != "linear":
            raise ValueError(f"expected a 'linear' artifact, got "
                             f"{artifact.kind!r}")
        return cls(int(artifact.meta["k"]), int(artifact.meta["n"]),
                   artifact.config, params=artifact.params)


class QuantConv2d(_Handle):
    """CIM conv2d handle: NHWC x, HWIO weight, stretched-kernel tiling."""

    kind = "conv"

    def __init__(self, kh: int, kw: int, c_in: int, c_out: int,
                 cfg: CIMConfig, *, stride: int = 1, padding: str = "SAME",
                 params: Optional[Dict[str, jnp.ndarray]] = None):
        super().__init__(cfg, params)
        self.kh, self.kw = int(kh), int(kw)
        self.c_in, self.c_out = int(c_in), int(c_out)
        self.stride, self.padding = int(stride), padding

    def init(self, key: jax.Array, *, dtype=jnp.float32) -> "QuantConv2d":
        self.params = _init_conv(key, self.kh, self.kw, self.c_in,
                                 self.c_out, self.cfg, dtype)
        return self

    def calibrate(self, x: jnp.ndarray) -> "QuantConv2d":
        self.params = _calibrate_conv(x, self._require_trainable("calibrate"),
                                      self.cfg, stride=self.stride,
                                      padding=self.padding)
        return self

    def __call__(self, x: jnp.ndarray, *,
                 variation: Optional[Variation] = None,
                 compute_dtype=jnp.float32) -> jnp.ndarray:
        vkey, vstd = _vkv(variation)
        return _conv_forward(x, self._require_params("__call__"), self.cfg,
                             stride=self.stride, padding=self.padding,
                             variation_key=vkey, variation_std=vstd,
                             compute_dtype=compute_dtype)

    def pack(self, *, variation: Optional[Variation] = None,
             meta: Optional[Dict] = None) -> DeployArtifact:
        vkey, vstd = _vkv(variation)
        _, pack_cv = packers_for(_packed_config(self.cfg))
        packed = pack_cv(self._require_trainable("pack"), self.cfg,
                         variation_key=vkey, variation_std=vstd)
        m = {"kh": self.kh, "kw": self.kw, "c_in": self.c_in,
             "c_out": self.c_out, "stride": self.stride,
             "padding": self.padding, **(meta or {}),
             "col_shard": {"": -1}}
        return DeployArtifact(kind="conv", config=_packed_config(self.cfg),
                              params=packed, meta=m)

    @classmethod
    def from_artifact(cls, artifact: DeployArtifact) -> "QuantConv2d":
        if artifact.kind != "conv":
            raise ValueError(f"expected a 'conv' artifact, got "
                             f"{artifact.kind!r}")
        m = artifact.meta
        return cls(int(m["kh"]), int(m["kw"]), int(m["c_in"]),
                   int(m["c_out"]), artifact.config,
                   stride=int(m.get("stride", 1)),
                   padding=m.get("padding", "SAME"),
                   params=artifact.params)
