"""Versioned on-disk deploy artifacts: the unit a served model loads.

A ``DeployArtifact`` is the packed, self-describing deployment state of
one CIM layer or a whole model tree: int digit planes, learned scales,
the ``CIMConfig`` that produced them (pinned to a packed backend) and a
layout-version tag. ``save``/``load`` are built on ``repro.checkpoint``
(atomic rename, raw-byte leaves) so the round trip is **bit-exact** —
including int4 planes and variation-baked (float) planes — and a pack
benched today is byte-identical to the pack a server loads tomorrow.

On-disk layout::

    <path>/
      artifact.json        kind, layout_version, config, meta
      step_00000000/       repro.checkpoint leaf store for ``params``

``pack_model`` generalizes the per-layer pack to arbitrary param trees:
any dict node carrying the CIM-layer quartet {w, s_w, s_p, s_a} is
packed (linear for 2-D weights, conv for 4-D HWIO; stacked
scan-over-layers variants vmap over the leading layer axis), and MoE
expert banks — flat ``nm``/``nm_s_w``/``nm_s_p``/``nm_s_a`` keys with a
leading expert axis — pack per expert into ``nm_digits`` planes with
per-expert column scales. Every other node — embeddings, norms, biases,
full-precision stems — passes through untouched.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as _ckpt
from repro.core.cim_linear import CIMConfig
from repro.core.variation import path_fold_key

# Layout 2 adds the optional per-node ``deq_scale`` leaf (in-service
# recalibration, eval/recalibrate.py); layout 3 stamps the packing
# backend (``head["backend"]`` == config.mode, DESIGN.md §13) so tools
# can see which hardware style an artifact targets from artifact.json
# alone. Layout 4 (DESIGN.md §14) stores int4 digit planes nibble-packed
# (two 4-bit digits per uint8 byte along the row/channel-slice axis) and
# adds a per-(split, array tile, column) ``w_occ`` occupancy map next to
# every plane. Readers of 4 still read 1-3: ``load`` migrates older
# standard-pack artifacts in memory (``_migrate_pre_v4``) bit-exactly.
ARTIFACT_LAYOUT_VERSION = 4

# Version of the ScaleDelta side-artifact format (eval/recalibrate.py).
# Stamped into a delta at fit time and into ``artifact.meta`` at apply
# time; load() refuses artifacts recalibrated by a newer delta format.
SCALE_DELTA_VERSION = 1

# Which PR introduced each on-disk format version — named in version-
# mismatch errors so "which side is stale" is answerable from the message.
_LAYOUT_WRITERS = {1: "PR 3 (lifecycle API)", 2: "PR 6 (self-healing serving)",
                   3: "PR 9 (hardware-style backends)",
                   4: "PR 10 (nibble planes + occupancy)"}
_DELTA_WRITERS = {1: "PR 6 (self-healing serving)"}

_KINDS = ("linear", "conv", "model")


class ArtifactVersionError(ValueError):
    """A DeployArtifact or ScaleDelta carries a format version this build
    cannot honor — too new to read, or (for a ScaleDelta) fitted against
    a different artifact layout than the one it is being applied to.
    Subclasses ValueError for compatibility with callers that caught the
    old untyped load error. Carries ``field``/``found``/``supported`` so
    tooling can triage without parsing the message."""

    def __init__(self, what: str, field: str, found, supported: int, *,
                 writers: Optional[Dict[int, str]] = None, relation: str = "<=",
                 detail: str = ""):
        self.field, self.found, self.supported = field, found, supported
        writers = writers or {}
        by = writers.get(found) if isinstance(found, int) else None
        ours = writers.get(supported)
        msg = (f"{what} has {field} {found!r}"
               + (f" (written by {by})" if by else "")
               + f"; this build expects {field} {relation} {supported}"
               + (f" (writer: {ours})" if ours else "") + ".")
        if detail:
            msg += " " + detail
        super().__init__(msg)


def _migrate_pre_v4(params, cfg: CIMConfig):
    """In-memory migration of a layout 1-3 params tree to layout 4.

    For every digit-plane leaf (``*_digits``) of a standard-pack backend:

      * add the sibling ``*_occ`` occupancy map (computed from the planes
        as stored — for variation-baked float planes this is still
        exact: multiplicative noise keeps zero cells zero);
      * nibble-pack dense int4 planes two-per-byte when the packed axis
        is even (``repro.core.nibble``). int8 / float planes and odd
        axes keep their dense storage.

    The decode path is unchanged arithmetic, so a migrated artifact
    serves bit-exact with the bytes it was written with
    (tests/test_artifact_migration.py). Backends with their own pack
    format (``pack_linear``/``pack_conv`` set, e.g. ``binary``) are
    passed through untouched — their planes are not the standard digit
    layout and their forwards do not consume occupancy maps.
    """
    from repro.core.nibble import (can_pack_nibbles, occupancy_map,
                                   pack_nibbles)
    from .backends import get_backend
    b = get_backend(cfg.mode)
    if b.pack_linear is not None or b.pack_conv is not None:
        return params

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, (dict, list, tuple)):
                    out[k] = walk(v)
                    continue
                out[k] = v
                if not k.endswith("_digits"):
                    continue
                d = jnp.asarray(v)
                # conv planes are always the quartet key "w_digits" with
                # the 6-D (or stacked 7-D) geometry shape; every other
                # rank — incl. rank-5/6 expert banks — is linear
                conv = k == "w_digits" and d.ndim >= 6
                occ_key = k[: -len("_digits")] + "_occ"
                if occ_key not in node:
                    out[occ_key] = occupancy_map(d, conv=conv)
                if (jnp.dtype(d.dtype) == jnp.dtype(jnp.int4)
                        and can_pack_nibbles(d.shape[-2], d.dtype)):
                    out[k] = pack_nibbles(d)
            return out
        if isinstance(node, (list, tuple)):
            return [walk(v) for v in node]
        return node
    return walk(params)


def _packed_config(cfg: CIMConfig) -> CIMConfig:
    """Pin the artifact's config to a packed backend (deploy by default)."""
    from .backends import get_backend
    if get_backend(cfg.mode).packed:
        return cfg
    return cfg.replace(mode="deploy")


@dataclasses.dataclass(frozen=True)
class DeployArtifact:
    """Packed deployment state: digit planes + scales + config + version.

    ``params`` is the packed tree the deploy/ref backends consume
    directly (``w_digits`` digit planes, ``s_w``/``s_p``/``s_a`` scales;
    for ``kind="model"`` the whole packed model tree). ``config`` always
    names a packed backend, so ``forward(x, artifact.params,
    artifact.config)`` is the served fast path with no further mode
    surgery. ``meta`` carries layer geometry (k/n, conv stride/padding)
    and free-form provenance.
    """

    kind: str                              # linear | conv | model
    config: CIMConfig
    params: Dict[str, Any]
    layout_version: int = ARTIFACT_LAYOUT_VERSION
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown artifact kind {self.kind!r}; "
                             f"valid: {_KINDS}")
        from .backends import get_backend
        if not get_backend(self.config.mode).packed:
            raise ValueError(
                f"DeployArtifact.config must name a packed backend, got "
                f"mode={self.config.mode!r}; use config.replace("
                "mode='deploy') (packing helpers do this for you)")

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> str:
        """Write the artifact; ``artifact.json`` lands last (fsynced +
        renamed), so its presence marks a complete artifact. When
        overwriting an existing artifact the stale header is removed
        *before* the new params land — a crash mid-overwrite leaves an
        incomplete (loudly unloadable) artifact, never new params paired
        with an old header."""
        os.makedirs(path, exist_ok=True)
        stale = os.path.join(path, "artifact.json")
        if os.path.exists(stale):
            os.remove(stale)
        _ckpt.save(path, 0, self.params)
        head = {
            "format": "repro.api.DeployArtifact",
            "layout_version": self.layout_version,
            "kind": self.kind,
            # which hardware-style backend the pack targets (== config
            # mode; layout >= 3) — surfaced in the header so placement/
            # fleet tools can route without opening the leaf store
            "backend": self.config.mode,
            "config": dataclasses.asdict(self.config),
            "meta": self.meta,
        }
        jpath = os.path.join(path, "artifact.json")
        tmp = jpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(head, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, jpath)   # atomic: never a headers/params mismatch
        return path

    @classmethod
    def load(cls, path: str, *, mesh=None,
             mesh_axis: str = "model") -> "DeployArtifact":
        """Read an artifact back, bit-exactly. With ``mesh``, each CIM
        node's digit planes (and full-column scales) are placed
        column-sharded over ``mesh_axis`` as they come off disk — every
        device receives only its own column shard of the host buffer, so
        no device ever materializes a full plane (DESIGN.md §10)."""
        jpath = os.path.join(path, "artifact.json")
        if not os.path.exists(jpath):
            raise FileNotFoundError(
                f"{path} is not a DeployArtifact (no artifact.json)")
        with open(jpath) as f:
            head = json.load(f)
        version = head.get("layout_version")
        if version is None or version > ARTIFACT_LAYOUT_VERSION:
            raise ArtifactVersionError(
                f"artifact at {path}", "layout_version", version,
                ARTIFACT_LAYOUT_VERSION, writers=_LAYOUT_WRITERS,
                detail="Upgrade the repro library or re-pack the artifact.")
        meta = dict(head.get("meta", {}))
        dv = meta.get("delta_version")
        if dv is not None and dv > SCALE_DELTA_VERSION:
            raise ArtifactVersionError(
                f"artifact at {path} (recalibrated)", "delta_version", dv,
                SCALE_DELTA_VERSION, writers=_DELTA_WRITERS,
                detail="Upgrade the repro library or re-fit the ScaleDelta "
                       "with eval/recalibrate.py.")
        try:
            cfg = CIMConfig(**head["config"])
        except ValueError as e:
            if "unknown CIM mode" not in str(e):
                raise
            from .backends import registered_backends
            backend = head.get("backend", head["config"].get("mode"))
            raise ValueError(
                f"artifact at {path} was packed for backend {backend!r}, "
                f"which is not registered in this session (registered: "
                f"{registered_backends()}). Import or register_backend() "
                f"the backend that owns this hardware style before "
                f"loading.") from None
        params = _ckpt.restore_tree(path, step=0)
        if version < 4:
            # older standard-pack artifacts load into the v4 in-memory
            # layout (nibble planes + occupancy), bit-exact on serve
            params = _migrate_pre_v4(params, cfg)
            version = ARTIFACT_LAYOUT_VERSION
        if mesh is None:
            params = jax.tree.map(jnp.asarray, params)
        art = cls(kind=head["kind"], config=cfg, params=params,
                  layout_version=version, meta=meta)
        if mesh is not None:
            # shard() device_puts straight from the restored host (numpy)
            # buffers: each device receives only its own column slice; the
            # full plane is never committed to any single device
            art = art.shard(mesh, mesh_axis=mesh_axis)
        return art

    def shard(self, mesh, *, mesh_axis: str = "model") -> "DeployArtifact":
        """Place the packed params on ``mesh``: digit planes and their
        full-column scales sharded along the output-column axis (the
        layout the column-parallel deploy path consumes in place — no
        per-call resharding), everything else replicated.

        Columns that do not divide the shard count stay replicated; the
        kernel wrapper pads and shards them per call instead (same rule as
        its last-block padding), so ragged layers still serve correctly.

        Leaves may be host (numpy) buffers — ``load(mesh=...)`` passes
        them through un-materialized, so ``device_put`` here sends each
        device only its own column slice and the full plane never lands
        on any single device.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        n_dev = int(mesh.shape[mesh_axis])
        rep = NamedSharding(mesh, P())

        def place(node):
            if isinstance(node, dict):
                if n_dev > 1 and any(k.endswith("_digits") for k in node):
                    return _shard_node(node, mesh, mesh_axis, n_dev, rep,
                                       place)
                return {k: place(v) for k, v in node.items()}
            if isinstance(node, (list, tuple)):
                return [place(v) for v in node]
            return jax.device_put(node, rep)
        return dataclasses.replace(self, params=place(self.params))


# ---------------------------------------------------------------------------
# generic model packing
# ---------------------------------------------------------------------------

_CIM_LAYER_KEYS = frozenset({"w", "s_w", "s_p", "s_a"})


def _is_cim_layer(node: Dict) -> bool:
    return (isinstance(node, dict) and _CIM_LAYER_KEYS <= set(node)
            and getattr(node["w"], "ndim", 0) >= 2)


# per-node key derivation shared with drift injection and delta fitting
_path_key = path_fold_key

_BANK_SCALES = ("s_w", "s_p", "s_a")


def _bank_names(node: Dict) -> list:
    """Expert-bank weights inside a dict node: array-valued keys ``nm`` of
    rank 3 ((E, K, N)) or 4 ((L, E, K, N) under ``stack_specs``) whose
    per-expert scales ride alongside as ``nm_s_w``/``nm_s_p``/``nm_s_a``
    (the ``models.layers.moe_specs`` flat-bank convention). The quartet
    convention never collides: a quartet's scales are unprefixed."""
    return [nm for nm, v in node.items()
            if getattr(v, "ndim", 0) in (3, 4)
            and all(f"{nm}_{s}" in node for s in _BANK_SCALES)]


def _pack_bank(node: Dict, nm: str, cfg: CIMConfig, vkey, variation_std,
               pack_lin=None):
    """Pack one expert bank: vmap the backend's linear packer over the
    flattened leading (layer-stack x expert) axes, then restore them.
    Outputs keep the flat-key convention (``nm_digits``/``nm_s_w``/... )
    so router and shared-expert siblings stay untouched in the same
    node."""
    if pack_lin is None:
        from .backends import packers_for
        pack_lin, _ = packers_for(cfg)
    bank = {"w": jnp.asarray(node[nm]).astype(jnp.float32),
            **{s: node[f"{nm}_{s}"] for s in _BANK_SCALES}}
    lead = bank["w"].shape[:-2]
    nl = len(lead)
    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[nl:]), bank)
    if vkey is None:
        packed = jax.vmap(lambda p: pack_lin(p, cfg))(flat)
    else:
        keys = jax.random.split(vkey, flat["w"].shape[0])
        packed = jax.vmap(lambda p, k: pack_lin(
            p, cfg, variation_key=k,
            variation_std=variation_std))(flat, keys)
    packed = jax.tree.map(lambda a: a.reshape(lead + a.shape[1:]), packed)
    out = {f"{nm}_digits": packed["w_digits"],
           f"{nm}_k_logical": packed["k_logical"],
           **{f"{nm}_{s}": packed[s] for s in _BANK_SCALES}}
    if "w_occ" in packed:   # layout v4 standard pack; custom packs may omit
        out[f"{nm}_occ"] = packed["w_occ"]
    return out


def pack_model(params: Dict, cfg: CIMConfig, *,
               variation_key: Optional[jax.Array] = None,
               variation_std=None) -> Dict:
    """Walk a model param tree, packing every CIM layer for deployment.

    A node is a CIM layer iff it carries {w, s_w, s_p, s_a}: 2-D ``w`` is
    a linear layer, 4-D an HWIO conv; 3-D/5-D are their stacked
    (scan-over-layers) forms, packed with a vmap over the layer axis.
    MoE expert banks (flat ``nm``/``nm_s_w``/``nm_s_p``/``nm_s_a`` keys,
    rank 3/4 with leading expert/layer axes) pack per expert into
    ``nm_digits`` planes with per-expert column scales — router dispatch
    (``models.layers._expert_matmul``) picks the packed planes up at
    call time. Full-precision nodes (no scales) pass through, so the
    same walk handles ResNets (fp stem/fc, BN), transformers
    (embeddings, norms, stacked blocks), SSM scan stacks and routers.

    ``variation_key``/``variation_std`` bake ONE device realization into
    the planes, with an independent per-layer key folded from the tree
    path (deterministic across processes).

    The packers are the BACKEND's (``backends.packers_for``): a cfg on a
    hardware style with its own pack path (e.g. ``binary``'s sign-plane
    pack) walks the same tree into that style's plane format."""
    from .backends import packers_for
    pack_lin, pack_cv = packers_for(_packed_config(cfg))

    def walk(node, path):
        if _is_cim_layer(node):
            w = node["w"]
            vkey = (None if variation_key is None
                    else _path_key(variation_key, path))
            kw = dict(variation_key=vkey, variation_std=variation_std)
            layer = {k: node[k] for k in _CIM_LAYER_KEYS}
            # non-quartet keys (e.g. a bias) ride along untouched
            extras = {k: v for k, v in node.items()
                      if k not in _CIM_LAYER_KEYS}
            if w.ndim == 2:
                return {**extras, **pack_lin(layer, cfg, **kw)}
            if w.ndim == 4:
                return {**extras, **pack_cv(layer, cfg, **kw)}
            if w.ndim in (3, 5):
                pack = pack_lin if w.ndim == 3 else pack_cv
                if vkey is None:
                    packed = jax.vmap(lambda p: pack(p, cfg))(layer)
                else:
                    keys = jax.random.split(vkey, w.shape[0])
                    packed = jax.vmap(lambda p, k: pack(
                        p, cfg, variation_key=k,
                        variation_std=variation_std))(layer, keys)
                return {**extras, **packed}
            raise ValueError(f"CIM layer at {'/'.join(path)} has "
                             f"unsupported weight rank {w.ndim}")
        if isinstance(node, dict):
            banks = _bank_names(node)
            if banks:
                out: Dict = {}
                consumed = set()
                for nm in banks:
                    vkey = (None if variation_key is None
                            else _path_key(variation_key, path + (nm,)))
                    out.update(_pack_bank(node, nm, cfg, vkey, variation_std,
                                          pack_lin=pack_lin))
                    consumed |= {nm, *(f"{nm}_{s}" for s in _BANK_SCALES)}
                # siblings (router, shared experts, ...) walk as usual
                for k, v in node.items():
                    if k not in consumed:
                        out[k] = walk(v, path + (k,))
                return out
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            # recurse so CIM layers inside sequences are packed, and
            # normalize tuples to lists: checkpoint.restore_tree rebuilds
            # sequence nodes as lists, so normalizing here keeps the
            # in-memory pack and a loaded artifact structure-exact
            return [walk(v, path + (str(i),)) for i, v in enumerate(node)]
        return node
    return walk(params, ())


def col_shard_axes(packed: Dict) -> Dict[str, int]:
    """Map every packed CIM node ('/'-joined tree path) to the axis its
    digit planes shard over for column-parallel serving — always the last
    axis (N for linear planes, C_out for conv planes; the stacked 5-D/7-D
    forms keep it last too). Stamped into model artifacts as
    ``meta["col_shard"]`` so external serving tools can plan placement
    from ``artifact.json`` alone, without opening the leaf store.
    (``DeployArtifact.shard`` itself re-derives the same layout
    structurally from the params tree, so a stale meta can never
    misplace a plane.)"""
    out: Dict[str, int] = {}

    def walk(node, path):
        if isinstance(node, dict):
            if "w_digits" in node:
                out["/".join(path)] = -1
                return
            for k in node:
                # expert banks: one entry per bank, keyed path/<bank name>
                if k.endswith("_digits"):
                    out["/".join(path + (k[: -len("_digits")],))] = -1
            for k, v in node.items():
                walk(v, path + (k,))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
    walk(packed, ())
    return out


def _shard_node(node: Dict, mesh, mesh_axis: str, n_dev: int, rep,
                place) -> Dict:
    """Place one packed CIM node: arrays carrying their bank's column axis
    (last dim == the planes' column count) shard over ``mesh_axis`` when
    the columns divide the device count; everything else replicates.
    Ragged banks stay replicated — the kernel wrapper pads and shards
    them per call (the last-shard padding rule, DESIGN.md §10).

    A quartet node has one bank (``w_digits`` owning the unprefixed
    ``s_w``/``s_p``/``s_a``/``deq_scale``); a MoE node carries several
    (``wg_digits`` owning ``wg_s_w``/... ). Sub-dict siblings (router,
    shared experts) recurse through ``place``."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    banks = {k[: -len("_digits")]: int(node[k].shape[-1])
             for k in node if k.endswith("_digits")}

    def bank_cols(k):
        for nm, n in banks.items():
            if k == f"{nm}_digits" or (nm != "w" and k.startswith(f"{nm}_")):
                return n
        return banks.get("w")   # quartet: unprefixed scale keys

    out = {}
    for k, v in node.items():
        if isinstance(v, (dict, list, tuple)):
            out[k] = place(v)
            continue
        n = bank_cols(k)
        cols = (n is not None and hasattr(v, "ndim") and v.ndim >= 1
                and v.shape[-1] == n and n % n_dev == 0)
        sh = (NamedSharding(mesh, P(*([None] * (v.ndim - 1) + [mesh_axis])))
              if cols else rep)
        out[k] = jax.device_put(v, sh)
    return out


def model_artifact(params: Dict, cfg: CIMConfig, *,
                   meta: Optional[Dict[str, Any]] = None,
                   variation_key: Optional[jax.Array] = None,
                   variation_std=None) -> DeployArtifact:
    """``pack_model`` + wrap into a saveable model ``DeployArtifact``.
    The shardable column axis of every packed node is recorded in
    ``meta["col_shard"]`` (see ``col_shard_axes``)."""
    packed = pack_model(params, cfg, variation_key=variation_key,
                        variation_std=variation_std)
    # col_shard last: the computed map wins over a caller-supplied key
    m = {**(meta or {}), "col_shard": col_shard_axes(packed)}
    return DeployArtifact(kind="model", config=_packed_config(cfg),
                          params=packed, meta=m)
