"""Versioned on-disk deploy artifacts: the unit a served model loads.

A ``DeployArtifact`` is the packed, self-describing deployment state of
one CIM layer or a whole model tree: int digit planes, learned scales,
the ``CIMConfig`` that produced them (pinned to a packed backend) and a
layout-version tag. ``save``/``load`` are built on ``repro.checkpoint``
(atomic rename, raw-byte leaves) so the round trip is **bit-exact** —
including int4 planes and variation-baked (float) planes — and a pack
benched today is byte-identical to the pack a server loads tomorrow.

On-disk layout::

    <path>/
      artifact.json        kind, layout_version, config, meta
      step_00000000/       repro.checkpoint leaf store for ``params``

``pack_model`` generalizes the per-layer pack to arbitrary param trees:
any dict node carrying the CIM-layer quartet {w, s_w, s_p, s_a} is
packed (linear for 2-D weights, conv for 4-D HWIO; stacked
scan-over-layers variants vmap over the leading layer axis); every other
node — embeddings, norms, biases, full-precision stems, MoE expert
banks — passes through untouched.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as _ckpt
from repro.core.cim_conv import _pack_conv
from repro.core.cim_linear import CIMConfig, _pack_linear

ARTIFACT_LAYOUT_VERSION = 1

_KINDS = ("linear", "conv", "model")


def _packed_config(cfg: CIMConfig) -> CIMConfig:
    """Pin the artifact's config to a packed backend (deploy by default)."""
    from .backends import get_backend
    if get_backend(cfg.mode).packed:
        return cfg
    return cfg.replace(mode="deploy")


@dataclasses.dataclass(frozen=True)
class DeployArtifact:
    """Packed deployment state: digit planes + scales + config + version.

    ``params`` is the packed tree the deploy/ref backends consume
    directly (``w_digits`` digit planes, ``s_w``/``s_p``/``s_a`` scales;
    for ``kind="model"`` the whole packed model tree). ``config`` always
    names a packed backend, so ``forward(x, artifact.params,
    artifact.config)`` is the served fast path with no further mode
    surgery. ``meta`` carries layer geometry (k/n, conv stride/padding)
    and free-form provenance.
    """

    kind: str                              # linear | conv | model
    config: CIMConfig
    params: Dict[str, Any]
    layout_version: int = ARTIFACT_LAYOUT_VERSION
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown artifact kind {self.kind!r}; "
                             f"valid: {_KINDS}")
        from .backends import get_backend
        if not get_backend(self.config.mode).packed:
            raise ValueError(
                f"DeployArtifact.config must name a packed backend, got "
                f"mode={self.config.mode!r}; use config.replace("
                "mode='deploy') (packing helpers do this for you)")

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> str:
        """Write the artifact; ``artifact.json`` lands last (fsynced +
        renamed), so its presence marks a complete artifact. When
        overwriting an existing artifact the stale header is removed
        *before* the new params land — a crash mid-overwrite leaves an
        incomplete (loudly unloadable) artifact, never new params paired
        with an old header."""
        os.makedirs(path, exist_ok=True)
        stale = os.path.join(path, "artifact.json")
        if os.path.exists(stale):
            os.remove(stale)
        _ckpt.save(path, 0, self.params)
        head = {
            "format": "repro.api.DeployArtifact",
            "layout_version": self.layout_version,
            "kind": self.kind,
            "config": dataclasses.asdict(self.config),
            "meta": self.meta,
        }
        jpath = os.path.join(path, "artifact.json")
        tmp = jpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(head, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, jpath)   # atomic: never a headers/params mismatch
        return path

    @classmethod
    def load(cls, path: str) -> "DeployArtifact":
        jpath = os.path.join(path, "artifact.json")
        if not os.path.exists(jpath):
            raise FileNotFoundError(
                f"{path} is not a DeployArtifact (no artifact.json)")
        with open(jpath) as f:
            head = json.load(f)
        version = head.get("layout_version")
        if version is None or version > ARTIFACT_LAYOUT_VERSION:
            raise ValueError(
                f"artifact at {path} has layout_version {version!r}; this "
                f"build reads versions <= {ARTIFACT_LAYOUT_VERSION}. "
                "Upgrade the repro library or re-pack the artifact.")
        cfg = CIMConfig(**head["config"])
        params = jax.tree.map(jnp.asarray, _ckpt.restore_tree(path, step=0))
        return cls(kind=head["kind"], config=cfg, params=params,
                   layout_version=version, meta=dict(head.get("meta", {})))


# ---------------------------------------------------------------------------
# generic model packing
# ---------------------------------------------------------------------------

_CIM_LAYER_KEYS = frozenset({"w", "s_w", "s_p", "s_a"})


def _is_cim_layer(node: Dict) -> bool:
    return (isinstance(node, dict) and _CIM_LAYER_KEYS <= set(node)
            and getattr(node["w"], "ndim", 0) >= 2)


def _path_key(key: jax.Array, path: tuple) -> jax.Array:
    h = 0
    for part in path:
        for ch in str(part):
            h = (h * 131 + ord(ch)) % (2 ** 31 - 1)
        h = (h * 131 + 7) % (2 ** 31 - 1)
    return jax.random.fold_in(key, h)


def pack_model(params: Dict, cfg: CIMConfig, *,
               variation_key: Optional[jax.Array] = None,
               variation_std=None) -> Dict:
    """Walk a model param tree, packing every CIM layer for deployment.

    A node is a CIM layer iff it carries {w, s_w, s_p, s_a}: 2-D ``w`` is
    a linear layer, 4-D an HWIO conv; 3-D/5-D are their stacked
    (scan-over-layers) forms, packed with a vmap over the layer axis.
    Full-precision nodes (no scales) pass through, so the same walk
    handles ResNets (fp stem/fc, BN), transformers (embeddings, norms,
    stacked blocks) and MoE trees (expert banks stay emulate — their
    deploy story is per-expert packing, not digit planes in a scan).

    ``variation_key``/``variation_std`` bake ONE device realization into
    the planes, with an independent per-layer key folded from the tree
    path (deterministic across processes)."""
    def walk(node, path):
        if _is_cim_layer(node):
            w = node["w"]
            vkey = (None if variation_key is None
                    else _path_key(variation_key, path))
            kw = dict(variation_key=vkey, variation_std=variation_std)
            layer = {k: node[k] for k in _CIM_LAYER_KEYS}
            # non-quartet keys (e.g. a bias) ride along untouched
            extras = {k: v for k, v in node.items()
                      if k not in _CIM_LAYER_KEYS}
            if w.ndim == 2:
                return {**extras, **_pack_linear(layer, cfg, **kw)}
            if w.ndim == 4:
                return {**extras, **_pack_conv(layer, cfg, **kw)}
            if w.ndim in (3, 5):
                pack = _pack_linear if w.ndim == 3 else _pack_conv
                if vkey is None:
                    packed = jax.vmap(lambda p: pack(p, cfg))(layer)
                else:
                    keys = jax.random.split(vkey, w.shape[0])
                    packed = jax.vmap(lambda p, k: pack(
                        p, cfg, variation_key=k,
                        variation_std=variation_std))(layer, keys)
                return {**extras, **packed}
            raise ValueError(f"CIM layer at {'/'.join(path)} has "
                             f"unsupported weight rank {w.ndim}")
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            # recurse so CIM layers inside sequences are packed, and
            # normalize tuples to lists: checkpoint.restore_tree rebuilds
            # sequence nodes as lists, so normalizing here keeps the
            # in-memory pack and a loaded artifact structure-exact
            return [walk(v, path + (str(i),)) for i, v in enumerate(node)]
        return node
    return walk(params, ())


def model_artifact(params: Dict, cfg: CIMConfig, *,
                   meta: Optional[Dict[str, Any]] = None,
                   variation_key: Optional[jax.Array] = None,
                   variation_std=None) -> DeployArtifact:
    """``pack_model`` + wrap into a saveable model ``DeployArtifact``."""
    packed = pack_model(params, cfg, variation_key=variation_key,
                        variation_std=variation_std)
    return DeployArtifact(kind="model", config=_packed_config(cfg),
                          params=packed, meta=dict(meta or {}))
