"""Public CIM-layer lifecycle API: quantize -> calibrate -> pack -> serve.

One vocabulary for every CIM layer (DESIGN.md §9):

* **Handles** — ``QuantLinear`` / ``QuantConv2d`` with uniform
  ``init(key) -> calibrate(x) -> __call__(x, variation=...) -> pack()``.
* **Functional layer** — ``init_linear``/``linear``/``calibrate_linear``/
  ``pack_linear`` and the ``*_conv``/``conv2d`` counterparts: the same
  lifecycle on explicit param trees, for jit/grad QAT loops.
* **Backends** — the ``Backend`` registry (``off``/``emulate``/
  ``deploy``/``ref``) behind ``CIMConfig.mode``; register new execution
  strategies with ``register_backend``.
* **Artifacts** — ``DeployArtifact`` (versioned, bit-exact save/load of
  packed digit planes + scales + config) and ``pack_model``/
  ``model_artifact`` for whole param trees.

The pre-API entry points (``repro.core.init_cim_linear``, ``cim_linear``,
``pack_deploy``, conv counterparts, ``models.resnet.pack_deploy``) remain
as deprecated shims; see the migration table in README.md.
"""
from repro.core.cim_conv import _calibrate_conv as calibrate_conv
from repro.core.cim_conv import _conv_forward as conv2d
from repro.core.cim_conv import _init_conv as init_conv
from repro.core.cim_linear import CIMConfig
from repro.core.cim_linear import _calibrate_linear as calibrate_linear
from repro.core.cim_linear import _init_linear as init_linear
from repro.core.cim_linear import _linear_forward as linear

from .artifact import (ARTIFACT_LAYOUT_VERSION, SCALE_DELTA_VERSION,
                       ArtifactVersionError, DeployArtifact, _packed_config,
                       col_shard_axes, model_artifact, pack_model)
from .backends import (Backend, get_backend, is_packed, packers_for,
                       register_backend, registered_backends)
from .handles import QuantConv2d, QuantLinear, Variation


def pack_linear(params, cfg, *, variation_key=None, variation_std=None):
    """Pack trainable linear params with ``cfg``'s backend packer — the
    standard deploy digit-plane pack unless the backend overrides it
    (e.g. ``binary``'s S=1 sign-plane pack). Non-packed cfgs (emulate)
    pack for ``deploy``."""
    pack_lin, _ = packers_for(_packed_config(cfg))
    return pack_lin(params, cfg, variation_key=variation_key,
                    variation_std=variation_std)


def pack_conv(params, cfg, *, variation_key=None, variation_std=None):
    """Conv counterpart of ``pack_linear`` (backend-resolved packer)."""
    _, pack_cv = packers_for(_packed_config(cfg))
    return pack_cv(params, cfg, variation_key=variation_key,
                   variation_std=variation_std)


__all__ = [
    "ARTIFACT_LAYOUT_VERSION", "ArtifactVersionError", "Backend", "CIMConfig",
    "DeployArtifact", "SCALE_DELTA_VERSION",
    "QuantConv2d", "QuantLinear", "Variation", "calibrate_conv",
    "calibrate_linear", "col_shard_axes", "conv2d", "get_backend",
    "init_conv", "init_linear", "is_packed", "linear", "model_artifact",
    "pack_conv", "pack_linear", "pack_model", "packers_for",
    "register_backend", "registered_backends",
]
