"""Public CIM-layer lifecycle API: quantize -> calibrate -> pack -> serve.

One vocabulary for every CIM layer (DESIGN.md §9):

* **Handles** — ``QuantLinear`` / ``QuantConv2d`` with uniform
  ``init(key) -> calibrate(x) -> __call__(x, variation=...) -> pack()``.
* **Functional layer** — ``init_linear``/``linear``/``calibrate_linear``/
  ``pack_linear`` and the ``*_conv``/``conv2d`` counterparts: the same
  lifecycle on explicit param trees, for jit/grad QAT loops.
* **Backends** — the ``Backend`` registry (``off``/``emulate``/
  ``deploy``/``ref``) behind ``CIMConfig.mode``; register new execution
  strategies with ``register_backend``.
* **Artifacts** — ``DeployArtifact`` (versioned, bit-exact save/load of
  packed digit planes + scales + config) and ``pack_model``/
  ``model_artifact`` for whole param trees.

The pre-API entry points (``repro.core.init_cim_linear``, ``cim_linear``,
``pack_deploy``, conv counterparts, ``models.resnet.pack_deploy``) remain
as deprecated shims; see the migration table in README.md.
"""
from repro.core.cim_conv import _calibrate_conv as calibrate_conv
from repro.core.cim_conv import _conv_forward as conv2d
from repro.core.cim_conv import _init_conv as init_conv
from repro.core.cim_conv import _pack_conv as pack_conv
from repro.core.cim_linear import CIMConfig
from repro.core.cim_linear import _calibrate_linear as calibrate_linear
from repro.core.cim_linear import _init_linear as init_linear
from repro.core.cim_linear import _linear_forward as linear
from repro.core.cim_linear import _pack_linear as pack_linear

from .artifact import (ARTIFACT_LAYOUT_VERSION, SCALE_DELTA_VERSION,
                       ArtifactVersionError, DeployArtifact,
                       col_shard_axes, model_artifact, pack_model)
from .backends import (Backend, get_backend, is_packed, register_backend,
                       registered_backends)
from .handles import QuantConv2d, QuantLinear, Variation

__all__ = [
    "ARTIFACT_LAYOUT_VERSION", "ArtifactVersionError", "Backend", "CIMConfig",
    "DeployArtifact", "SCALE_DELTA_VERSION",
    "QuantConv2d", "QuantLinear", "Variation", "calibrate_conv",
    "calibrate_linear", "col_shard_axes", "conv2d", "get_backend",
    "init_conv", "init_linear", "is_packed", "linear", "model_artifact",
    "pack_conv", "pack_linear", "pack_model", "register_backend",
    "registered_backends",
]
