"""Execution-backend registry: the single dispatch point for CIM layers.

A ``Backend`` bundles the linear and conv forward implementations for one
execution strategy. ``CIMConfig.mode`` is now just a *name* that resolves
here — the config never encodes arithmetic, and an unregistered name
fails at ``CIMConfig`` construction (``core.cim_linear._KNOWN_MODES``),
not at trace time.

Builtins (registered on import):

  off      full-precision baseline (plain matmul / XLA conv).
  emulate  paper-faithful QAT path: LSQ fake-quant, bit-split digits,
           per-array integer partial sums materialized for gradients.
  deploy   packed-int inference through the fused Pallas kernels
           (``cfg.use_kernel=False`` falls back to the jnp oracle for
           portable HLO) — bit-exact with ``emulate``. Mesh-aware: when a
           session mesh with a >1-device ``"model"`` axis is installed
           (serving engine / launchers), the packed planes dispatch
           column-sharded, one kernel shard per device (DESIGN.md §10).
  ref      packed-int inference forced onto the jnp oracle regardless of
           ``cfg.use_kernel`` — the arbitration reference for kernel
           debugging and backend-equivalence tests.

``register_backend`` accepts additional strategies (e.g. a noise-injected
canary or a per-accelerator kernel variant); registration makes the name
a valid ``CIMConfig.mode`` everywhere — handles, model zoo, serving.

Backend callables take positional tails so the dispatch sites stay
uniform:

  linear(x, params, cfg, variation_key, sigma, compute_dtype)
  conv(x, params, cfg, stride, padding, variation_key, sigma,
       compute_dtype)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import sys

import repro.core.cim_conv
import repro.core.cim_linear

# ``repro.core``'s __init__ re-exports same-named *functions* (the
# deprecated shims), shadowing the submodule attributes — resolve the
# modules through sys.modules.
_conv = sys.modules["repro.core.cim_conv"]
_lin = sys.modules["repro.core.cim_linear"]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution strategy for every CIM layer kind.

    ``packed=True`` backends consume deploy-packed params (int digit
    planes, ``w_digits``); ``packed=False`` backends consume the trainable
    float-weight params (``w``). ``repro.nn.linear.linear_specs`` and
    ``models.layers.conv_specs`` key their parameter layout off this flag.

    Hardware-style backends (DESIGN.md §13) may additionally own their
    packing and plane geometry:

    ``pack_linear``/``pack_conv`` convert trainable float params into this
    backend's packed form — same signatures as the core packers
    (``(params, cfg, *, variation_key, variation_std) -> packed``). When
    ``None`` (the default), the backend consumes the standard deploy pack
    (``core.cim_linear._pack_linear`` / ``core.cim_conv._pack_conv``);
    ``repro.api.pack_model``/``pack_linear``/``pack_conv`` and the handle
    ``.pack()`` methods all resolve through ``packers_for``.

    ``plane_bits`` overrides the (weight_bits, cell_bits) pair that
    determines the PACKED digit-plane geometry — e.g. the ``binary``
    style packs S=1 sign planes (plane_bits=(1, 1)) regardless of the
    config's training-time weight_bits. ``plane_tiling``/``conv_plane_
    tiling`` below resolve the packed geometry for spec construction.
    """

    name: str
    linear: Callable        # (x, params, cfg, vkey, sigma, compute_dtype)
    conv: Callable          # (x, params, cfg, stride, padding, vkey, sigma,
                            #  compute_dtype)
    packed: bool
    description: str = ""
    pack_linear: Optional[Callable] = None
    pack_conv: Optional[Callable] = None
    plane_bits: Optional[Tuple[int, int]] = None


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend, *, replace: bool = False) -> Backend:
    """Register a backend; its name becomes a valid ``CIMConfig.mode``.

    Name collisions raise unless ``replace=True`` — silently shadowing a
    built-in (or any registered) backend would reroute every dispatch
    site in the process."""
    if not replace and backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} is already registered; "
                         "pass replace=True to replace it")
    _REGISTRY[backend.name] = backend
    _lin._KNOWN_MODES.add(backend.name)
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown CIM backend {name!r}; registered: "
                       f"{registered_backends()}") from None


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def is_packed(cfg) -> bool:
    """True when ``cfg``'s backend consumes packed digit planes
    (``w_digits``) rather than the trainable float weight. This is what
    ``linear_specs``/``conv_specs`` key their parameter layout off."""
    if cfg is None or not cfg.enabled:
        return False
    return get_backend(cfg.mode).packed


def packers_for(cfg) -> Tuple[Callable, Callable]:
    """(pack_linear, pack_conv) for ``cfg``'s backend — the standard
    deploy packers unless the backend overrides them (e.g. ``binary``'s
    sign-plane pack). Every generic pack entry point (``pack_model``,
    handle ``.pack()``, ``repro.api.pack_linear``/``pack_conv``) resolves
    here so a ``cfg.replace(mode="binary")`` re-pack Just Works."""
    b = get_backend(cfg.mode)
    return (b.pack_linear or _lin._pack_linear,
            b.pack_conv or _conv._pack_conv)


def has_own_pack(cfg) -> bool:
    """True when ``cfg``'s backend packs its own plane format (e.g.
    ``binary``'s sign planes). Such planes keep dense storage: the v4
    nibble/occupancy layout (``linear_specs``/``conv_specs`` shapes, the
    artifact migration) applies only to the standard deploy pack."""
    b = get_backend(cfg.mode)
    return b.pack_linear is not None or b.pack_conv is not None


def plane_bits(cfg) -> Tuple[int, int]:
    """(weight_bits, cell_bits) governing ``cfg``'s PACKED digit-plane
    geometry — the backend's ``plane_bits`` override when set (binary:
    (1, 1) sign planes), else the config's own bits."""
    b = get_backend(cfg.mode)
    return b.plane_bits or (cfg.weight_bits, cfg.cell_bits)


def plane_tiling(cfg, k: int, n: int):
    """ArrayTiling of ``cfg``'s packed linear digit planes. Differs from
    ``cfg.tiling`` exactly when the backend overrides ``plane_bits``."""
    from repro.core.granularity import ArrayTiling
    wb, cb = plane_bits(cfg)
    return ArrayTiling(k=k, n=n, array_rows=cfg.array_rows,
                       array_cols=cfg.array_cols,
                       weight_bits=wb, cell_bits=cb)


def conv_plane_tiling(cfg, kh: int, kw: int, c_in: int, c_out: int):
    """(ArrayTiling, c_per_array) of ``cfg``'s packed conv digit planes
    under the stretched-kernel rule, honoring backend ``plane_bits``."""
    from repro.core.granularity import conv_tiling
    wb, cb = plane_bits(cfg)
    return conv_tiling(kh, kw, c_in, c_out, cfg.array_rows, cfg.array_cols,
                       wb, cb)


# ---------------------------------------------------------------------------
# builtins
# ---------------------------------------------------------------------------

def _linear_ref(x, params, cfg, vkey, sigma, compute_dtype):
    return _lin._forward_deploy(x, params, cfg.replace(use_kernel=False),
                                vkey, sigma, compute_dtype)


def _conv_ref(x, params, cfg, stride, padding, vkey, sigma, compute_dtype):
    return _conv._forward_conv_deploy(x, params,
                                      cfg.replace(use_kernel=False),
                                      stride, padding, vkey, sigma,
                                      compute_dtype)


register_backend(Backend(
    name="off",
    linear=_lin._forward_off,
    conv=_conv._forward_conv_off,
    packed=False,
    description="full-precision baseline (no quantization)"))

register_backend(Backend(
    name="emulate",
    linear=_lin._forward_emulate,
    conv=_conv._forward_conv_emulate,
    packed=False,
    description="differentiable QAT path; partial sums materialized so "
                "LSQ gradients flow through the ADC"))

register_backend(Backend(
    name="deploy",
    linear=_lin._forward_deploy,
    conv=_conv._forward_conv_deploy,
    packed=True,
    description="packed int digit planes on the fused Pallas kernels "
                "(jnp oracle when cfg.use_kernel=False)"))

register_backend(Backend(
    name="ref",
    linear=_linear_ref,
    conv=_conv_ref,
    packed=True,
    description="packed int digit planes on the jnp oracle (kernel "
                "arbitration reference)"))


# Hardware-style backends (adc_free, binary — DESIGN.md §13) live in
# ``repro.backends``; imported last so their ``register_backend`` calls
# find Backend/register_backend already defined on this partially-
# initialized module (import-cycle safe).
import repro.backends  # noqa: E402,F401  (registers adc_free, binary)
