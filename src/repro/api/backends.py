"""Execution-backend registry: the single dispatch point for CIM layers.

A ``Backend`` bundles the linear and conv forward implementations for one
execution strategy. ``CIMConfig.mode`` is now just a *name* that resolves
here — the config never encodes arithmetic, and an unregistered name
fails at ``CIMConfig`` construction (``core.cim_linear._KNOWN_MODES``),
not at trace time.

Builtins (registered on import):

  off      full-precision baseline (plain matmul / XLA conv).
  emulate  paper-faithful QAT path: LSQ fake-quant, bit-split digits,
           per-array integer partial sums materialized for gradients.
  deploy   packed-int inference through the fused Pallas kernels
           (``cfg.use_kernel=False`` falls back to the jnp oracle for
           portable HLO) — bit-exact with ``emulate``. Mesh-aware: when a
           session mesh with a >1-device ``"model"`` axis is installed
           (serving engine / launchers), the packed planes dispatch
           column-sharded, one kernel shard per device (DESIGN.md §10).
  ref      packed-int inference forced onto the jnp oracle regardless of
           ``cfg.use_kernel`` — the arbitration reference for kernel
           debugging and backend-equivalence tests.

``register_backend`` accepts additional strategies (e.g. a noise-injected
canary or a per-accelerator kernel variant); registration makes the name
a valid ``CIMConfig.mode`` everywhere — handles, model zoo, serving.

Backend callables take positional tails so the dispatch sites stay
uniform:

  linear(x, params, cfg, variation_key, sigma, compute_dtype)
  conv(x, params, cfg, stride, padding, variation_key, sigma,
       compute_dtype)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import sys

import repro.core.cim_conv
import repro.core.cim_linear

# ``repro.core``'s __init__ re-exports same-named *functions* (the
# deprecated shims), shadowing the submodule attributes — resolve the
# modules through sys.modules.
_conv = sys.modules["repro.core.cim_conv"]
_lin = sys.modules["repro.core.cim_linear"]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution strategy for every CIM layer kind.

    ``packed=True`` backends consume deploy-packed params (int digit
    planes, ``w_digits``); ``packed=False`` backends consume the trainable
    float-weight params (``w``). ``repro.nn.linear.linear_specs`` and
    ``models.layers.conv_specs`` key their parameter layout off this flag.
    """

    name: str
    linear: Callable        # (x, params, cfg, vkey, sigma, compute_dtype)
    conv: Callable          # (x, params, cfg, stride, padding, vkey, sigma,
                            #  compute_dtype)
    packed: bool
    description: str = ""


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Register a backend; its name becomes a valid ``CIMConfig.mode``."""
    if not overwrite and backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} is already registered; "
                         "pass overwrite=True to replace it")
    _REGISTRY[backend.name] = backend
    _lin._KNOWN_MODES.add(backend.name)
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown CIM backend {name!r}; registered: "
                       f"{registered_backends()}") from None


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def is_packed(cfg) -> bool:
    """True when ``cfg``'s backend consumes packed digit planes
    (``w_digits``) rather than the trainable float weight. This is what
    ``linear_specs``/``conv_specs`` key their parameter layout off."""
    if cfg is None or not cfg.enabled:
        return False
    return get_backend(cfg.mode).packed


# ---------------------------------------------------------------------------
# builtins
# ---------------------------------------------------------------------------

def _linear_ref(x, params, cfg, vkey, sigma, compute_dtype):
    return _lin._forward_deploy(x, params, cfg.replace(use_kernel=False),
                                vkey, sigma, compute_dtype)


def _conv_ref(x, params, cfg, stride, padding, vkey, sigma, compute_dtype):
    return _conv._forward_conv_deploy(x, params,
                                      cfg.replace(use_kernel=False),
                                      stride, padding, vkey, sigma,
                                      compute_dtype)


register_backend(Backend(
    name="off",
    linear=_lin._forward_off,
    conv=_conv._forward_conv_off,
    packed=False,
    description="full-precision baseline (no quantization)"))

register_backend(Backend(
    name="emulate",
    linear=_lin._forward_emulate,
    conv=_conv._forward_conv_emulate,
    packed=False,
    description="differentiable QAT path; partial sums materialized so "
                "LSQ gradients flow through the ADC"))

register_backend(Backend(
    name="deploy",
    linear=_lin._forward_deploy,
    conv=_conv._forward_conv_deploy,
    packed=True,
    description="packed int digit planes on the fused Pallas kernels "
                "(jnp oracle when cfg.use_kernel=False)"))

register_backend(Backend(
    name="ref",
    linear=_linear_ref,
    conv=_conv_ref,
    packed=True,
    description="packed int digit planes on the jnp oracle (kernel "
                "arbitration reference)"))
