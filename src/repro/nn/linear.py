"""CIM-aware dense layer: one entry point for every stored-weight matmul.

``linear_specs`` emits the weight plus — when CIM quantization is enabled —
the paper's learnable scale factors (s_w at weight granularity, s_p at psum
granularity, s_a for activations) with shardings aligned to the weight's
output axis; ``apply_linear`` dispatches to the plain matmul or the CIM
forward (emulate/deploy).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.cim_linear import CIMConfig, _linear_forward
from .module import ParamSpec


def linear_specs(
    k: int,
    n: int,
    *,
    cim: Optional[CIMConfig] = None,
    in_axis: Optional[str] = None,
    out_axis: Optional[str] = None,
    dtype=jnp.float32,
    init: str | None = None,
) -> Dict[str, ParamSpec]:
    from repro.api.backends import (has_own_pack, is_packed, plane_bits,
                                    plane_tiling)  # lazy: api builds on nn
    w_init = init or "fan_in:1.0"
    packed = is_packed(cim)
    if packed:
        # packed-int inference: weights live ONLY as digit planes. The
        # out_axis lands on the planes' LAST axis (N) — the column-shard
        # axis of the mesh-aware deploy path (DESIGN.md §10) — so spec-
        # initialized packed params are born in the served layout. The
        # plane geometry is the BACKEND's (binary packs S=1 sign planes),
        # not necessarily the config's training-time bit widths.
        t = plane_tiling(cim, k, n)
        own_pack = has_own_pack(cim)
        if own_pack:
            # plane-geometry backends (binary) keep dense plane storage
            rows_s, store = t.array_rows, cim.store_dtype()
        else:
            # standard v4 pack: int4 planes store nibble-packed (uint8,
            # half the rows) and carry a w_occ occupancy map
            from repro.core.nibble import stored_rows
            rows_s, store = stored_rows(t.array_rows, cim.store_dtype())
        specs = {"w_digits": ParamSpec(
            (t.n_split, t.k_tiles, rows_s, n), store,
            "zeros", (None, None, None, out_axis))}
        if not own_pack:
            specs["w_occ"] = ParamSpec(
                (t.n_split, t.k_tiles, n), jnp.uint8, "zeros",
                (None, None, out_axis))
    else:
        specs = {"w": ParamSpec((k, n), dtype, w_init, (in_axis, out_axis))}
    if cim is not None and cim.enabled:
        if packed and plane_bits(cim) != (cim.weight_bits, cim.cell_bits):
            # plane-geometry backends (binary) store FULL column-
            # granularity scales — granularity.broadcast_* is shape-
            # driven, so any cfg granularity still reads them at forward.
            from repro.core.granularity import Granularity
            t = plane_tiling(cim, k, n)
            wg = t.weight_scale_shape(Granularity.COLUMN)
            pg = t.psum_scale_shape(Granularity.COLUMN)
        else:
            t = cim.tiling(k, n)
            wg = t.weight_scale_shape(cim.weight_granularity)
            pg = t.psum_scale_shape(cim.psum_granularity)
        # scales follow the weight's output-axis sharding when they have a
        # full-N axis; tile-level axes stay replicated.
        w_sp = (None, out_axis if wg[1] == n else None)
        p_sp = (None, None, out_axis if pg[2] == n else None)
        specs["s_w"] = ParamSpec(wg, jnp.float32, "const:0.05", w_sp)
        specs["s_p"] = ParamSpec(pg, jnp.float32, "const:8.0", p_sp)
        specs["s_a"] = ParamSpec((1,), jnp.float32, "ones", (None,))
    return specs


def apply_linear(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cim: Optional[CIMConfig] = None,
    *,
    compute_dtype=jnp.bfloat16,
    variation_key: Optional[jax.Array] = None,
    variation_std=None,
) -> jnp.ndarray:
    if cim is None or not cim.enabled:
        return jnp.dot(x.astype(compute_dtype),
                       params["w"].astype(compute_dtype))
    return _linear_forward(x, params, cim, variation_key=variation_key,
                           variation_std=variation_std,
                           compute_dtype=compute_dtype)
