"""Minimal functional module system.

Models are pairs of pure functions:

  specs(cfg)  -> nested dict of ParamSpec   (shapes, dtypes, init, sharding)
  apply(params, inputs, cfg) -> outputs

ParamSpec carries *logical* axis names ("embed", "vocab", "heads", ...);
``resolve_pspec`` maps them onto mesh axes through a rules table, so the
same model runs on a (data, model) pod mesh, a (pod, data, model)
multi-pod mesh, or a single CPU device (empty rules). Parameters are only
ever materialized through ``init_params`` (real run) or
``eval_shape_params`` (allocation-free dry-run).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

InitFn = Callable[[jax.Array, Tuple[int, ...], Any], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    init: Union[str, InitFn] = "normal:0.02"
    pspec: Optional[Tuple[Optional[str], ...]] = None  # logical axes

    def initializer(self) -> InitFn:
        if callable(self.init):
            return self.init
        kind, _, arg = self.init.partition(":")
        if kind == "zeros":
            return lambda k, s, d: jnp.zeros(s, d)
        if kind == "ones":
            return lambda k, s, d: jnp.ones(s, d)
        if kind == "const":
            v = float(arg)
            return lambda k, s, d: jnp.full(s, v, d)
        if kind == "normal":
            std = float(arg) if arg else 0.02
            return lambda k, s, d: (jax.random.normal(k, s, jnp.float32) * std).astype(d)
        if kind == "fan_in":
            # truncated-normal-ish scaled by 1/sqrt(fan_in) (last-2 dim)
            def f(k, s, d):
                fan = s[-2] if len(s) >= 2 else s[-1]
                return (jax.random.normal(k, s, jnp.float32)
                        * (float(arg) if arg else 1.0) / jnp.sqrt(fan)).astype(d)
            return f
        raise ValueError(f"unknown init {self.init!r}")


def _walk(tree, path=()):
    if isinstance(tree, ParamSpec):
        yield path, tree
        return
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], path + (k,))
        return
    raise TypeError(f"bad spec node at {path}: {type(tree)}")


def init_params(specs, key: jax.Array):
    """Materialize parameters; per-leaf keys are derived from the path, so
    adding/removing parameters never reshuffles other leaves."""
    def build(tree, path=()):
        if isinstance(tree, ParamSpec):
            leaf_key = jax.random.fold_in(key, _path_hash(path))
            return tree.initializer()(leaf_key, tree.shape, tree.dtype)
        return {k: build(v, path + (k,)) for k, v in tree.items()}
    return build(specs)


def _path_hash(path: Tuple[str, ...]) -> int:
    h = 0
    for part in path:
        for ch in str(part):
            h = (h * 131 + ord(ch)) % (2 ** 31 - 1)
        h = (h * 131 + 7) % (2 ** 31 - 1)
    return h


def eval_shape_params(specs):
    """ShapeDtypeStructs for every parameter — no allocation."""
    def build(tree):
        if isinstance(tree, ParamSpec):
            return jax.ShapeDtypeStruct(tree.shape, tree.dtype)
        return {k: build(v) for k, v in tree.items()}
    return build(specs)


def resolve_pspec(logical: Optional[Tuple[Optional[str], ...]],
                  rules: Dict[str, Any]) -> P:
    """Map logical axis names to mesh axes, dropping duplicates (a mesh
    axis may appear at most once in a PartitionSpec)."""
    if logical is None:
        return P()
    used = set()
    out = []
    for ax in logical:
        target = rules.get(ax) if ax is not None else None
        if target is None:
            out.append(None)
            continue
        taxes = tuple(target) if isinstance(target, (tuple, list)) else (target,)
        taxes = tuple(t for t in taxes if t not in used)
        for t in taxes:
            used.add(t)
        out.append(taxes if len(taxes) > 1 else (taxes[0] if taxes else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_to_mesh(specs, rules: Dict[str, Any]):
    """Tree of PartitionSpecs resolved from the logical annotations."""
    def build(tree):
        if isinstance(tree, ParamSpec):
            return resolve_pspec(tree.pspec, rules)
        return {k: build(v) for k, v in tree.items()}
    return build(specs)


def param_shardings(specs, mesh, rules: Dict[str, Any]):
    def build(tree):
        if isinstance(tree, ParamSpec):
            return NamedSharding(mesh, resolve_pspec(tree.pspec, rules))
        return {k: build(v) for k, v in tree.items()}
    return build(specs)


# ---------------------------------------------------------------------------
# activation sharding context: the launcher installs mesh rules; models call
# constrain() with logical axes and run unchanged on a single device (no-op).
# ---------------------------------------------------------------------------
_ACTIVATION_RULES: Dict[str, Any] = {}
_CURRENT_MESH = None


def set_activation_rules(rules: Optional[Dict[str, Any]], mesh=None) -> None:
    global _ACTIVATION_RULES, _CURRENT_MESH
    _ACTIVATION_RULES = dict(rules) if rules else {}
    _CURRENT_MESH = mesh


def current_mesh():
    return _CURRENT_MESH


@contextlib.contextmanager
def session_mesh(mesh, rules: Optional[Dict[str, Any]] = None):
    """Scope a session mesh: install ``mesh`` (+ optional activation
    rules) on entry, restore the previous mesh/rules on exit. The
    mesh-aware paths (column-sharded CIM deploy, EP MoE, flash decode)
    read ``current_mesh()`` at *trace* time, so run both tracing and
    execution inside the scope — or use ``set_activation_rules`` directly
    for a process-lifetime install (what serving processes do)."""
    prev_rules, prev_mesh = dict(_ACTIVATION_RULES), _CURRENT_MESH
    set_activation_rules(rules if rules is not None else prev_rules, mesh)
    try:
        yield mesh
    finally:
        set_activation_rules(prev_rules, prev_mesh)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-compat shard_map: newer jax exposes ``jax.shard_map`` with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map.shard_map`` with
    the same flag spelled ``check_rep``."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def current_rules() -> Dict[str, Any]:
    return dict(_ACTIVATION_RULES)


def constrain(x, logical: Tuple[Optional[str], ...]):
    if not _ACTIVATION_RULES:
        return x
    spec = resolve_pspec(logical, _ACTIVATION_RULES)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        # no mesh in scope (single-device tracing): constraints are hints
        return x


def stack_specs(specs, n: int):
    """Prepend a layer axis (for lax.scan-over-layers parameter stacking)."""
    def build(tree):
        if isinstance(tree, ParamSpec):
            ps = (None,) + tree.pspec if tree.pspec is not None else None
            base_init = tree.initializer()

            def stacked_init(k, s, d, _init=base_init):
                keys = jax.random.split(k, s[0])
                return jax.vmap(lambda kk: _init(kk, s[1:], d))(keys)

            return ParamSpec(shape=(n,) + tree.shape, dtype=tree.dtype,
                             init=stacked_init, pspec=ps)
        return {k: build(v) for k, v in tree.items()}
    return build(specs)
