from .module import (ParamSpec, constrain, eval_shape_params, init_params,
                     logical_to_mesh, param_shardings, resolve_pspec,
                     set_activation_rules, stack_specs)
from .linear import apply_linear, linear_specs

__all__ = [
    "ParamSpec", "apply_linear", "constrain", "eval_shape_params",
    "init_params", "linear_specs", "logical_to_mesh", "param_shardings",
    "resolve_pspec", "set_activation_rules", "stack_specs",
]
