"""Packed int4 nibble planes + per-(tile, column) plane occupancy.

Two storage-level levers the deploy kernels exploit (DESIGN.md §14):

**Nibble packing.** ``pack_dtype='int4'`` digit planes historically stored
int4 but *streamed* int8 (the kernel wrappers upcast before the
pallas_call), so HBM traffic on the decode path paid the full byte. Here
two 4-bit two's-complement digits pack into one uint8 along the plane's
row axis (axis -2) and the kernels decode them in VMEM — plane bytes on
the wire halve. The pairing is a **half-split**: row ``r`` of the packed
plane holds digit row ``r`` in its low nibble and digit row ``r + rows/2``
in its high nibble, so in-kernel decode is two shifts plus one
concatenate — no interleave. Only even row counts pack (odd counts keep
the dense int4 storage; the variation-noise contract draws noise over the
*logical* plane shape, which an odd-row pack could not reconstruct
without side-channel metadata).

``uint8`` is the discriminator: digit planes are otherwise int8 / int4 /
float32 (variation-baked), so a uint8 ``w_digits`` leaf always means
nibble-packed. The packed axis is always -2 — ``rows`` for linear
(S, kt, rows, N) planes, ``c_per_array`` for conv 6-D
(S, kt, kh, kw, cpa, C_out) planes — which keeps the trailing
column-shard axis untouched: shard boundaries stay byte-aligned for free.

**Occupancy.** ``occupancy_map`` reduces a digit plane to one byte per
(split, array tile, column) saying whether ANY cell in that column tile
is nonzero. The kernels skip the MACs of unoccupied planes; under the
sign ADC (psum_bits == 1) a skipped all-zero plane still contributes
``+s_p * deq`` on the dense path (psum 0 quantizes to +1), so the sparse
kernels fold exactly that compensation term in — sparse-skip output is
bit-exact with dense (tests/test_sparse_skip.py pins the grid).
Multiplicative cell variation keeps zeros zero, so an occupancy map
computed from clean digits stays valid under any noise realization.
"""
from __future__ import annotations

import jax.numpy as jnp

#: Storage dtype of nibble-packed digit planes — and their discriminator:
#: no other digit-plane storage uses uint8.
NIBBLE_DTYPE = jnp.uint8


def is_nibble_packed(planes) -> bool:
    """True when a digit-plane leaf is nibble-packed (uint8 storage)."""
    return jnp.dtype(planes.dtype) == jnp.dtype(NIBBLE_DTYPE)


def can_pack_nibbles(rows: int, store_dtype) -> bool:
    """Nibble packing applies iff the storage grid is int4 and the packed
    (row) axis is even — odd axes would need an extra metadata row to
    reconstruct the logical shape the variation noise is drawn over."""
    return jnp.dtype(store_dtype) == jnp.dtype(jnp.int4) and rows % 2 == 0


def stored_rows(rows: int, store_dtype):
    """(stored row count, storage dtype) of a digit plane's packed axis —
    the shape rule ``linear_specs``/``conv_specs``/the packers share."""
    if can_pack_nibbles(rows, store_dtype):
        return rows // 2, NIBBLE_DTYPE
    return rows, store_dtype


def pack_nibbles(planes: jnp.ndarray) -> jnp.ndarray:
    """Pack int4-valued digit planes two-per-byte along axis -2.

    planes: (..., rows, N) integer-valued digits in [-8, 7], rows even.
    Returns (..., rows // 2, N) uint8 — row ``r`` carries digit row ``r``
    (low nibble) and digit row ``r + rows // 2`` (high nibble), both as
    4-bit two's complement."""
    rows = planes.shape[-2]
    if rows % 2:
        raise ValueError(f"nibble packing needs an even packed axis, "
                         f"got {rows} (shape {planes.shape})")
    x = planes.astype(jnp.int32)
    lo, hi = jnp.split(x, 2, axis=-2)
    return ((lo & 0xF) | ((hi & 0xF) << 4)).astype(NIBBLE_DTYPE)


def unpack_nibbles(packed: jnp.ndarray, *, groups: int = 1) -> jnp.ndarray:
    """Invert ``pack_nibbles``: (..., rows_p, N) uint8 -> (..., 2*rows_p, N)
    int8 digits in [-8, 7].

    ``groups``: the packed axis holds ``groups`` independently-packed
    blocks. The canonical layouts always pack with groups=1 (linear rows,
    conv ``c_per_array``); the conv kernels see the 6-D plane *flattened*
    to (S, kt, kh*kw*cpa_p, C_out), where each of the kh*kw taps is its
    own packed block — unpacking there needs ``groups=kh*kw`` to restore
    the (dh, dw, c) row order ``extract_conv_patches`` produces."""
    rows_p = packed.shape[-2]
    if rows_p % groups:
        raise ValueError(f"packed axis {rows_p} not divisible by "
                         f"groups={groups}")
    x = packed.astype(jnp.int32)
    lo = ((x & 0xF) ^ 8) - 8            # 4-bit two's complement decode
    hi = ((x >> 4) ^ 8) - 8
    lead = packed.shape[:-2]
    gh = rows_p // groups
    n = packed.shape[-1]
    lo = lo.reshape(lead + (groups, gh, n))
    hi = hi.reshape(lead + (groups, gh, n))
    out = jnp.concatenate([lo, hi], axis=-2)
    return out.reshape(lead + (2 * rows_p, n)).astype(jnp.int8)


def occupancy_map(planes: jnp.ndarray, *, conv: bool = False) -> jnp.ndarray:
    """Per-(split, array tile, column) plane occupancy, uint8 {0, 1}.

    planes: *logical* (un-nibbled) digit planes — linear (..., S, kt,
    rows, N) or conv (..., S, kt, kh, kw, cpa, C_out) with ``conv=True``.
    A column tile is occupied iff any of its cells is nonzero; the deploy
    kernels skip the MACs of unoccupied planes (compensating the sign
    ADC's zero-plane output, see module docstring). Returns (..., S, kt,
    N) / (..., S, kt, C_out)."""
    axes = (-4, -3, -2) if conv else (-2,)
    return jnp.any(planes != 0, axis=axes).astype(jnp.uint8)
