"""CIM-mapped linear layer with column-wise weight + partial-sum quantization.

This is the paper's technique (§III-A, Eqs. 1-4) as a composable JAX module
usable by any architecture whose FLOPs live in stored-weight matmuls.

Execution backends (``CIMConfig.mode`` resolves through the
``repro.api.backends`` registry; the implementations live here):

  off      plain matmul in the compute dtype (full-precision baseline).
  emulate  paper-faithful QAT path: LSQ fake-quant of activations and
           weights (at the configured granularity), bit-split digits,
           per-array integer partial sums, ADC quantization of each
           (split, array, column) partial sum with learnable scales,
           fused dequantization s_a * s_w * s_p * 2^(c*s), shift-and-add.
  deploy   packed-int inference path: identical arithmetic evaluated by
           the Pallas kernel (kernels/cim_matmul) from pre-quantized int8
           digit planes - bit-exact with ``emulate`` (tests assert), but
           weights live in HBM as int8 so the memory-roofline term drops.
  ref      deploy arithmetic forced onto the jnp oracle (portable HLO).

The partial-sum tensor in ``emulate`` has shape (..., n_split, k_tiles, N);
the Pallas kernel never materializes it in HBM.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.obs import adc as obs_adc

from .bitsplit import place_values, split_digits
from .granularity import ArrayTiling, Granularity
from .nibble import (can_pack_nibbles, is_nibble_packed, occupancy_map,
                     pack_nibbles)
from .quantizer import init_scale_from, lsq_fake_quant, qrange
from .variation import perturb_digits, perturb_packed, variation_wanted

# Execution-mode names CIMConfig accepts. The builtins are the modes the
# core forwards implement; ``repro.api.backends.register_backend`` adds
# custom backend names here so a registered backend is a valid
# ``CIMConfig.mode`` and a typo fails at construction, not trace time.
_BUILTIN_MODES = ("off", "emulate", "deploy", "ref")
_KNOWN_MODES = set(_BUILTIN_MODES)

_PACK_DTYPES = ("int8", "int4")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} instead "
        "(see the migration table in README.md).",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """Quantization + CIM-mapping configuration (paper Table II knobs).

    ``mode`` names the execution backend (``repro.api.backends``):
    ``off`` | ``emulate`` | ``deploy`` | ``ref`` plus anything registered
    via ``register_backend``. Unknown modes, granularities or pack dtypes
    raise at construction — never silently at trace time.
    """

    enabled: bool = False
    mode: str = "emulate"            # backend name (see repro.api.backends)
    weight_bits: int = 4
    cell_bits: int = 2
    act_bits: int = 8
    psum_bits: int = 4
    array_rows: int = 128
    array_cols: int = 128
    weight_granularity: Granularity = Granularity.COLUMN
    psum_granularity: Granularity = Granularity.COLUMN
    act_signed: bool = True
    psum_quant: bool = True          # False -> paper's "w/o PSQ" baselines
    variation_std: float = 0.0       # eval-time log-normal cell noise
    use_kernel: bool = True          # deploy: Pallas kernel vs jnp reference
    pack_dtype: str = "int8"         # deploy digit storage: int8 | int4

    def __post_init__(self):
        if self.mode not in _KNOWN_MODES:
            raise ValueError(
                f"unknown CIM mode {self.mode!r}; registered backends: "
                f"{sorted(_KNOWN_MODES)}. Custom backends must be "
                "registered via repro.api.backends.register_backend "
                "before a CIMConfig can name them.")
        if self.pack_dtype not in _PACK_DTYPES:
            raise ValueError(f"unknown pack_dtype {self.pack_dtype!r}; "
                             f"valid: {_PACK_DTYPES}")
        for field in ("weight_granularity", "psum_granularity"):
            val = getattr(self, field)
            if not isinstance(val, Granularity):
                try:
                    coerced = Granularity(val)
                except ValueError:
                    raise ValueError(
                        f"unknown {field} {val!r}; valid: "
                        f"{[g.value for g in Granularity]}") from None
                object.__setattr__(self, field, coerced)
        for field in ("weight_bits", "cell_bits", "act_bits", "psum_bits",
                      "array_rows", "array_cols"):
            if int(getattr(self, field)) < 1:
                raise ValueError(f"{field} must be >= 1, got "
                                 f"{getattr(self, field)!r}")

    def tiling(self, k: int, n: int) -> ArrayTiling:
        return ArrayTiling(
            k=k, n=n,
            array_rows=self.array_rows, array_cols=self.array_cols,
            weight_bits=self.weight_bits, cell_bits=self.cell_bits,
        )

    def replace(self, **kw) -> "CIMConfig":
        fields = {f.name for f in dataclasses.fields(self)}
        unknown = sorted(set(kw) - fields)
        if unknown:
            raise TypeError(
                f"CIMConfig.replace: unknown field(s) {unknown}; "
                f"valid fields: {sorted(fields)}")
        return dataclasses.replace(self, **kw)

    def store_dtype(self):
        """Deploy digit-plane storage dtype: int4 when requested and the
        sign-magnitude digits fit [-7, 7] (cells of <=3 bits), else int8."""
        return (jnp.int4 if (self.pack_dtype == "int4"
                             and self.cell_bits <= 3) else jnp.int8)


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------

def _init_linear(
    key: jax.Array, k: int, n: int, cfg: CIMConfig, w_init_scale: float | None = None,
    dtype=jnp.float32,
) -> Dict[str, jnp.ndarray]:
    """Initialize {w, s_w, s_p, s_a} for a (k, n) CIM linear layer."""
    std = w_init_scale if w_init_scale is not None else (1.0 / jnp.sqrt(k))
    w = (jax.random.normal(key, (k, n), jnp.float32) * std).astype(dtype)
    params: Dict[str, jnp.ndarray] = {"w": w}
    if cfg.enabled:
        t = cfg.tiling(k, n)
        wg, pg = cfg.weight_granularity, cfg.psum_granularity
        params["s_w"] = weight_scales_from(w.astype(jnp.float32), cfg)
        # psum scale init: |P| ~ sqrt(rows)*E|a_int|*E|digit|; refined by
        # calibrate_cim() on the first batch and learned thereafter.
        _, qp_p = qrange(cfg.psum_bits, True)
        p_mag = jnp.sqrt(float(t.array_rows)) * (2 ** (cfg.act_bits - 2)) * (2 ** (cfg.cell_bits - 1)) / 2.0
        params["s_p"] = jnp.full(t.psum_scale_shape(pg), 2.0 * p_mag / jnp.sqrt(float(max(qp_p, 1))), jnp.float32)
        params["s_a"] = jnp.asarray([1.0], jnp.float32)
    return params


def weight_scales_from(w: jnp.ndarray, cfg: CIMConfig) -> jnp.ndarray:
    """Per-group LSQ scale init, s = 2 E|w|_group / sqrt(q_p) — the
    column-wise groups are each array column's weights (paper §III-A)."""
    k, n = w.shape
    t = cfg.tiling(k, n)
    _, qp = qrange(cfg.weight_bits, True)
    pad_k = t.k_padded - k
    w_abs = jnp.abs(jnp.pad(w, ((0, pad_k), (0, 0))))
    w_t = w_abs.reshape(t.k_tiles, t.array_rows, n)
    # real (unpadded) rows per tile
    rows = jnp.minimum(
        jnp.full((t.k_tiles,), t.array_rows),
        k - jnp.arange(t.k_tiles) * t.array_rows).astype(jnp.float32)
    m_col = w_t.sum(axis=1) / rows[:, None]
    g = cfg.weight_granularity
    if g == Granularity.COLUMN:
        s = m_col                                          # (kt, n)
    elif g == Granularity.ARRAY:
        pad_n = t.n_tiles * t.oc_per_array - n
        mc = jnp.pad(m_col, ((0, 0), (0, pad_n)))
        s = mc.reshape(t.k_tiles, t.n_tiles, t.oc_per_array).mean(-1)
    else:
        s = jnp.mean(m_col, keepdims=True).reshape(1, 1)
    return (2.0 * s / jnp.sqrt(float(max(qp, 1)))).astype(jnp.float32) + 1e-9


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------

def _full_weight_scale(params, t: ArrayTiling) -> jnp.ndarray:
    """(k_tiles, N) weight scale, differentiable w.r.t. the parameter."""
    return t.broadcast_weight_scale(params["s_w"])


def _full_psum_scale(params, t: ArrayTiling) -> jnp.ndarray:
    """(n_split, k_tiles, N) psum scale, differentiable w.r.t. the param."""
    return t.broadcast_psum_scale(params["s_p"])


def _quantize_weight_int(params, cfg: CIMConfig, t: ArrayTiling) -> jnp.ndarray:
    """Integer weight codes (K, N), float dtype, LSQ gradients attached."""
    w = params["w"].astype(jnp.float32)
    s_w = _full_weight_scale(params, t)                       # (kt, N)
    s_full = jnp.repeat(s_w, t.array_rows, axis=0)[: t.k]     # (K, N)
    w_hat = lsq_fake_quant(
        w, s_full, cfg.weight_bits, signed=True,
        group_size=t.weight_group_size(cfg.weight_granularity))
    return w_hat / jnp.maximum(s_full, 1e-9)


def _quantize_act(x, params, cfg: CIMConfig):
    """Returns (a_int, s_a) - integer activation codes and their scale."""
    s_a = params["s_a"]
    a_hat = lsq_fake_quant(x.astype(jnp.float32), s_a, cfg.act_bits,
                           signed=cfg.act_signed)
    return a_hat / jnp.maximum(s_a, 1e-9), s_a


def deploy_act_codes(x, s_a, cfg: CIMConfig) -> jnp.ndarray:
    """Integer activation codes for the packed inference paths.

    Shared by every packed backend (deploy/ref/adc_free/binary): clip-round
    x to the act_bits grid and narrow to the smallest integer dtype so HBM
    traffic drops to 1 byte/activation (the byte width
    bench_kernel.traffic_model charges)."""
    qn_a, qp_a = qrange(cfg.act_bits, cfg.act_signed)
    a_int = jnp.clip(
        jnp.round(x.astype(jnp.float32) / jnp.maximum(s_a, 1e-9)),
        qn_a, qp_a)
    if qn_a >= -128 and qp_a <= 127:
        a_int = a_int.astype(jnp.int8)
    elif qn_a >= 0 and qp_a <= 255:
        a_int = a_int.astype(jnp.uint8)   # unsigned 8-bit (post-ReLU) codes
    return a_int


def _tile_inputs(a_int: jnp.ndarray, t: ArrayTiling) -> jnp.ndarray:
    """(..., K) -> (..., k_tiles, rows) with zero padding."""
    pad = t.k_padded - a_int.shape[-1]
    if pad:
        a_int = jnp.pad(a_int, [(0, 0)] * (a_int.ndim - 1) + [(0, pad)])
    return a_int.reshape(a_int.shape[:-1] + (t.k_tiles, t.array_rows))


def _tile_digits(digits: jnp.ndarray, t: ArrayTiling) -> jnp.ndarray:
    """(S, K, N) -> (S, k_tiles, rows, N) with zero padding."""
    pad = t.k_padded - digits.shape[1]
    if pad:
        digits = jnp.pad(digits, ((0, 0), (0, pad), (0, 0)))
    return digits.reshape(t.n_split, t.k_tiles, t.array_rows, t.n)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _linear_forward(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    cfg: CIMConfig,
    *,
    variation_key: Optional[jax.Array] = None,
    variation_std=None,
    compute_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Apply a CIM linear layer: x (..., K) @ w (K, N) -> (..., N).

    ``cfg.mode`` resolves to a registered backend (repro.api.backends)
    which owns the arithmetic; the builtins are ``off`` (plain matmul),
    ``emulate`` (QAT fake-quant), ``deploy`` (packed Pallas kernel) and
    ``ref`` (packed jnp oracle).

    ``variation_std`` overrides ``cfg.variation_std`` without rebuilding
    the (static) config — it may be a traced scalar, so a Monte-Carlo
    sweep can feed a sigma grid through one jitted function. Emulate and
    deploy draw cell noise in the same packed layout from the same key,
    so they agree bit-exactly under variation too (DESIGN.md §8).
    """
    if not cfg.enabled:
        return _forward_off(x, params, cfg, None, None, compute_dtype)
    from repro.api.backends import get_backend  # lazy: api builds on core
    sigma = cfg.variation_std if variation_std is None else variation_std
    return get_backend(cfg.mode).linear(x, params, cfg, variation_key,
                                        sigma, compute_dtype)


def _forward_off(x, params, cfg, variation_key, sigma, compute_dtype):
    w = params["w"].astype(compute_dtype)
    return jnp.dot(x.astype(compute_dtype), w)


def _forward_emulate(x, params, cfg, variation_key, sigma, compute_dtype):
    k, n = params["w"].shape
    t = cfg.tiling(k, n)

    a_int, s_a = _quantize_act(x, params, cfg)                # (..., K)
    w_int = _quantize_weight_int(params, cfg, t)              # (K, N)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)  # (S,K,N)

    a_t = _tile_inputs(a_int, t).astype(compute_dtype)        # (..., kt, r)
    d_t = _tile_digits(digits, t)                             # (S, kt, r, N)
    if variation_wanted(variation_key, sigma):
        # noise is drawn over the TILED layout — the same (S, kt, rows, N)
        # shape pack_deploy stores — so deploy sees identical theta per cell
        d_t = perturb_digits(d_t, variation_key, sigma)
    d_t = d_t.astype(compute_dtype)

    # integer column MACs: one per (split, array-tile, column)
    psum = jnp.einsum("...tr,strn->...stn", a_t, d_t,
                      preferred_element_type=jnp.float32)     # (...,S,kt,N)

    if cfg.psum_quant:
        # psums are integer-valued (int x int MACs); snap float roundoff to
        # the grid so ADC tie-breaking matches the deploy kernel bit-exactly
        psum = psum + jax.lax.stop_gradient(jnp.round(psum) - psum)
        s_p = _full_psum_scale(params, t)                     # (S, kt, N)
        if obs_adc.enabled():
            # exact counters: emulate materializes every partial sum
            obs_adc.record(psum, s_p, cfg.psum_bits)
        psum = lsq_fake_quant(psum, s_p, cfg.psum_bits, signed=True)

    # fused dequantization (paper Eq. 3 / Fig. 4d): one scale per column
    s_w = _full_weight_scale(params, t)                       # (kt, N)
    places = place_values(cfg.weight_bits, cfg.cell_bits)     # (S,)
    deq = (places[:, None, None] * s_w[None, :, :])           # (S, kt, N)
    y = jnp.einsum("...stn,stn->...n", psum.astype(jnp.float32), deq)
    y = y * jnp.maximum(s_a, 1e-9)
    return y.astype(compute_dtype)


def _forward_deploy(x, params, cfg, variation_key, sigma, compute_dtype,
                    adc_free: bool = False):
    """Inference from packed int digit planes (see ``_pack_linear``). Cell
    noise is injected by the kernel wrapper on the packed planes — the
    int planes themselves are never re-packed per sample.

    When a mesh with a >1-device ``"model"`` axis is installed
    (``repro.nn.module.set_activation_rules(rules, mesh)`` — the serving
    engine and launchers do this), the digit planes run column-sharded
    over that axis: each device evaluates its own output-column shard and
    one all-gather merges the dequantized activations (DESIGN.md §10).

    ``adc_free=True`` dispatches the same packed planes onto the ADC-free
    hardware style (DESIGN.md §13): digital psum accumulation, no ADC
    quantization — the ``adc_free`` backend registration wraps this."""
    from repro.kernels import ops as kops  # lazy: avoids import cycle
    from repro.nn.module import current_mesh

    digits = params["w_digits"]                               # int (S,kt,r,N)
    if not variation_wanted(variation_key, sigma):
        variation_key = sigma = None

    s_a = params["s_a"]
    a_int = deploy_act_codes(x, s_a, cfg)
    # logical K from the activation; tiling geometry from the digit planes
    t = cfg.tiling(x.shape[-1], digits.shape[-1])
    rows_stored = (t.array_rows // 2 if is_nibble_packed(digits)
                   else t.array_rows)    # uint8 planes: half-split pack
    assert t.k_tiles == digits.shape[1] and rows_stored == digits.shape[2], \
        (t.k_tiles, t.array_rows, digits.shape)
    a_t = _tile_inputs(a_int, t)

    s_p = _full_psum_scale(params, t)
    s_w = _full_weight_scale(params, t)
    places = place_values(cfg.weight_bits, cfg.cell_bits)
    deq = places[:, None, None] * s_w[None] * jnp.maximum(s_a, 1e-9)
    if "deq_scale" in params:
        # in-service recalibration correction (eval/recalibrate.py): a
        # per-column dequant gain shipped as a ScaleDelta, (S, kt, N)
        deq = deq * params["deq_scale"]

    y = kops.cim_matmul(
        a_t, digits, s_p, deq,
        psum_bits=cfg.psum_bits, psum_quant=cfg.psum_quant,
        use_kernel=cfg.use_kernel,
        variation_key=variation_key, variation_std=sigma,
        mesh=current_mesh(), adc_free=adc_free,
        occ=params.get("w_occ"),
    )
    return y.astype(compute_dtype)


# ---------------------------------------------------------------------------
# packing + calibration
# ---------------------------------------------------------------------------

def _pack_linear(params: Dict[str, jnp.ndarray], cfg: CIMConfig, *,
                 variation_key: Optional[jax.Array] = None,
                 variation_std=None) -> Dict[str, jnp.ndarray]:
    """Convert trained emulate-mode params into the packed deploy form.

    pack_dtype='int4' stores each digit plane as int4 (sign-magnitude
    digits of <=3-bit cells fit [-7, 7]) — halves weight HBM vs int8 and
    is the deploy dtype the decode roofline uses.

    ``variation_key``/``variation_std`` bake ONE log-normal device
    realization into the packed planes (float32) — useful to freeze a
    specific chip's noise. For Monte-Carlo sweeps keep the planes clean
    and perturb lazily per sample instead: ``perturb_packed(packed, key,
    sigma, sample=i)`` or the ``variation_key`` forward argument.

    Layout v4 extras (DESIGN.md §14): ``w_occ`` — a per-(split, array
    tile, column) uint8 occupancy map the deploy kernels use to skip
    all-zero digit planes bit-exactly — and, for ``pack_dtype='int4'``
    with an even array-row count, half-split nibble packing of the
    planes (two digits per uint8 byte, ``repro.core.nibble``)."""
    k, n = params["w"].shape
    t = cfg.tiling(k, n)
    w_int = _quantize_weight_int(params, cfg, t)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)
    d_t = _tile_digits(digits, t).astype(cfg.store_dtype())
    occ = occupancy_map(d_t)
    if can_pack_nibbles(t.array_rows, cfg.store_dtype()):
        d_t = pack_nibbles(d_t)
    out = {
        "w_digits": d_t,
        "w_occ": occ,
        "s_w": params["s_w"],
        "s_p": params["s_p"],
        "s_a": params["s_a"],
        "k_logical": jnp.asarray(k, jnp.int32),
    }
    if variation_wanted(variation_key, variation_std):
        out = perturb_packed(out, variation_key, variation_std)
    return out


def _calibrate_linear(x, params, cfg: CIMConfig) -> Dict[str, jnp.ndarray]:
    """One-batch calibration of s_a and s_p (LSQ-style init from stats)."""
    if not cfg.enabled:
        return params
    k, n = params["w"].shape
    t = cfg.tiling(k, n)
    p = dict(params)
    _, qp_a = qrange(cfg.act_bits, cfg.act_signed)
    p["s_a"] = (2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(float(max(qp_a, 1)))
                ).reshape(1).astype(jnp.float32) + 1e-9

    a_int, _ = _quantize_act(x, p, cfg)
    w_int = _quantize_weight_int(p, cfg, t)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)
    a_t = _tile_inputs(a_int, t)
    d_t = _tile_digits(digits, t)
    psum = jnp.einsum("...tr,strn->...stn", a_t, d_t,
                      preferred_element_type=jnp.float32)
    flat = psum.reshape((-1,) + psum.shape[-3:])              # (B*, S, kt, N)
    _, qp_p = qrange(cfg.psum_bits, True)
    mean_abs = jnp.mean(jnp.abs(flat), axis=0)                # (S, kt, N)
    pg = cfg.psum_granularity
    if pg == Granularity.LAYER:
        s = jnp.mean(mean_abs, axis=(1, 2), keepdims=True)
    elif pg == Granularity.ARRAY:
        pad_n = t.n_tiles * t.oc_per_array - t.n
        ma = jnp.pad(mean_abs, ((0, 0), (0, 0), (0, pad_n)))
        s = jnp.mean(ma.reshape(t.n_split, t.k_tiles, t.n_tiles, t.oc_per_array), axis=-1)
    else:
        s = mean_abs
    p["s_p"] = (2.0 * s / jnp.sqrt(float(max(qp_p, 1)))).astype(jnp.float32) + 1e-9
    return p


# ---------------------------------------------------------------------------
# deprecated entry points (pre-`repro.api` surface)
# ---------------------------------------------------------------------------

def init_cim_linear(*args, **kw) -> Dict[str, jnp.ndarray]:
    """Deprecated: use ``repro.api.init_linear`` / ``QuantLinear.init``."""
    _deprecated("init_cim_linear", "repro.api.init_linear")
    return _init_linear(*args, **kw)


def cim_linear(*args, **kw) -> jnp.ndarray:
    """Deprecated: use ``repro.api.linear`` / ``QuantLinear.__call__``."""
    _deprecated("cim_linear", "repro.api.linear")
    return _linear_forward(*args, **kw)


def calibrate_cim(*args, **kw) -> Dict[str, jnp.ndarray]:
    """Deprecated: use ``repro.api.calibrate_linear``."""
    _deprecated("calibrate_cim", "repro.api.calibrate_linear")
    return _calibrate_linear(*args, **kw)


def pack_deploy(*args, **kw) -> Dict[str, jnp.ndarray]:
    """Deprecated: use ``repro.api.pack_linear`` / ``QuantLinear.pack``
    (which returns a versioned, saveable ``DeployArtifact``)."""
    _deprecated("pack_deploy", "repro.api.pack_linear")
    return _pack_linear(*args, **kw)
