"""Core library: the paper's column-wise weight + partial-sum quantization
for CIM accelerators, as composable JAX building blocks.

The per-layer lifecycle entry points exported here (``init_cim_linear``,
``cim_linear``, ``calibrate_cim``, ``pack_deploy`` and their conv
counterparts) are **deprecated shims** kept for downstream compatibility;
new code uses ``repro.api`` (typed handles, backend registry, versioned
``DeployArtifact``) — see the migration table in README.md."""
from .bitsplit import place_values, recombine, split_digits
from .cim_conv import (calibrate_cim_conv, cim_conv2d, conv_dequant_muls,
                       init_cim_conv, pack_deploy_conv)
from .cim_linear import (CIMConfig, calibrate_cim, cim_linear, init_cim_linear,
                         pack_deploy)
from .granularity import ArrayTiling, Granularity, conv_tiling, n_splits
from .nibble import (can_pack_nibbles, is_nibble_packed, occupancy_map,
                     pack_nibbles, stored_rows, unpack_nibbles)
from .quantizer import (init_scale_from, lsq_fake_quant, lsq_integer, qrange,
                        round_ste)
from .variation import (DriftSchedule, DriftState, apply_cell_variation,
                        drift_field, drift_tree, path_fold_key,
                        perturb_digits, perturb_packed, variation_noise)

__all__ = [
    "ArrayTiling", "CIMConfig", "DriftSchedule", "DriftState", "Granularity",
    "apply_cell_variation",
    "calibrate_cim", "calibrate_cim_conv", "can_pack_nibbles", "cim_conv2d",
    "cim_linear", "conv_dequant_muls",
    "conv_tiling", "drift_field", "drift_tree", "init_cim_conv",
    "init_cim_linear", "init_scale_from", "is_nibble_packed",
    "lsq_fake_quant", "lsq_integer", "n_splits", "occupancy_map",
    "pack_deploy", "pack_deploy_conv", "pack_nibbles", "path_fold_key",
    "perturb_digits", "perturb_packed",
    "place_values", "qrange", "recombine", "round_ste", "split_digits",
    "stored_rows", "unpack_nibbles", "variation_noise",
]
