"""Quantization granularity accounting for CIM arrays.

The paper's central object: a weight matrix W (K, N) is tiled into CIM
arrays of ``array_rows`` x ``array_cols`` cells. A b-bit weight occupies
``n_split = ceil(weight_bits / cell_bits)`` physical columns (bit-splits),
so an array holds ``oc_per_array = array_cols // n_split`` output channels.

Granularity defines which elements share one quantization scale factor:

  LAYER  - one scale for the whole layer              (paper Fig. 1a/d)
  ARRAY  - one scale per CIM array                    (paper Fig. 1b/e)
  COLUMN - one scale per physical array column        (paper Fig. 1c/f)

For weights the scale is indexed (k_tile, col); for partial sums the ADC
digitizes each (split, k_tile, col) physical column separately so scales
are indexed (split, k_tile, col). Scale *parameter* shapes collapse the
shared axes; ``broadcast_*`` expands them back for arithmetic.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Tuple

import jax.numpy as jnp


class Granularity(str, enum.Enum):
    LAYER = "layer"
    ARRAY = "array"
    COLUMN = "column"


def n_splits(weight_bits: int, cell_bits: int) -> int:
    return int(math.ceil(weight_bits / cell_bits))


@dataclasses.dataclass(frozen=True)
class ArrayTiling:
    """Static tiling of a (K, N) weight matrix onto CIM arrays."""

    k: int                  # logical contraction dim (rows of W)
    n: int                  # logical output dim (columns of W)
    array_rows: int
    array_cols: int
    weight_bits: int
    cell_bits: int

    @property
    def n_split(self) -> int:
        return n_splits(self.weight_bits, self.cell_bits)

    @property
    def k_tiles(self) -> int:
        return int(math.ceil(self.k / self.array_rows))

    @property
    def k_padded(self) -> int:
        return self.k_tiles * self.array_rows

    @property
    def oc_per_array(self) -> int:
        return max(1, self.array_cols // self.n_split)

    @property
    def n_tiles(self) -> int:
        """Arrays along the output dim."""
        return int(math.ceil(self.n / self.oc_per_array))

    @property
    def n_arrays(self) -> int:
        return self.k_tiles * self.n_tiles

    # -- scale parameter shapes ------------------------------------------------
    def weight_scale_shape(self, g: Granularity) -> Tuple[int, ...]:
        if g == Granularity.LAYER:
            return (1, 1)
        if g == Granularity.ARRAY:
            return (self.k_tiles, self.n_tiles)
        return (self.k_tiles, self.n)

    def psum_scale_shape(self, g: Granularity) -> Tuple[int, ...]:
        if g == Granularity.LAYER:
            return (self.n_split, 1, 1)
        if g == Granularity.ARRAY:
            return (self.n_split, self.k_tiles, self.n_tiles)
        return (self.n_split, self.k_tiles, self.n)

    # -- broadcasting to full logical shape -------------------------------------
    def broadcast_weight_scale(self, s: jnp.ndarray) -> jnp.ndarray:
        """Expand a weight-scale parameter to shape (k_tiles, N)."""
        if s.shape == (1, 1):
            return jnp.broadcast_to(s, (self.k_tiles, self.n))
        if s.shape == (self.k_tiles, self.n_tiles):
            rep = jnp.repeat(s, self.oc_per_array, axis=1)
            return rep[:, : self.n]
        assert s.shape == (self.k_tiles, self.n), s.shape
        return s

    def broadcast_psum_scale(self, s: jnp.ndarray) -> jnp.ndarray:
        """Expand a psum-scale parameter to shape (n_split, k_tiles, N)."""
        if s.shape == (self.n_split, 1, 1):
            return jnp.broadcast_to(s, (self.n_split, self.k_tiles, self.n))
        if s.shape == (self.n_split, self.k_tiles, self.n_tiles):
            rep = jnp.repeat(s, self.oc_per_array, axis=2)
            return rep[:, :, : self.n]
        assert s.shape == (self.n_split, self.k_tiles, self.n), s.shape
        return s

    # -- per-group element counts (LSQ gradient scaling) -------------------------
    def weight_group_size(self, g: Granularity) -> int:
        if g == Granularity.LAYER:
            return self.k * self.n
        if g == Granularity.ARRAY:
            return self.array_rows * self.oc_per_array
        return self.array_rows

    # -- hardware accounting (paper Fig. 4 / Fig. 8) ----------------------------
    def dequant_muls(self, weight_g: Granularity, psum_g: Granularity) -> int:
        """Scale multiplications needed to dequantize one layer's outputs.

        Reproduces the paper's Fig. 4 accounting: the fused scale
        ``s_w * s_p`` is applied once per distinct (weight-group, psum-group)
        pair that reaches the shift-and-add stage.  Aligning both at COLUMN
        costs exactly as much as LAYER-weight + COLUMN-psum — the paper's key
        zero-overhead observation.
        """
        order = {Granularity.LAYER: 0, Granularity.ARRAY: 1, Granularity.COLUMN: 2}
        finest = weight_g if order[weight_g] >= order[psum_g] else psum_g
        if finest == Granularity.LAYER:
            return 1
        if finest == Granularity.ARRAY:
            # one mul per output-channel per array (paper: n_array * n_oc)
            return self.n_arrays * self.oc_per_array
        # one mul per physical column (paper: n_split * n_array * n_oc)
        return self.n_split * self.n_arrays * self.oc_per_array


def conv_tiling(
    kh: int,
    kw: int,
    c_in: int,
    c_out: int,
    array_rows: int,
    array_cols: int,
    weight_bits: int,
    cell_bits: int,
) -> Tuple[ArrayTiling, int]:
    """Tiling for a conv layer under the paper's stretched-kernel rule.

    The paper's novel tiling (§III-C) keeps every stretched kernel column
    intact inside one array: the tiling stride along the contraction dim is
    ``c_per_array * kh * kw`` with ``c_per_array = floor(rows / (kh*kw))``,
    i.e. an array holds a slice of input channels with *all* their taps.
    The array MAC is then a convolution over that channel slice, which we
    realize as one grouped convolution (groups = k_tiles).

    Returns the tiling (with array_rows snapped to the used rows) and
    ``c_per_array``.
    """
    taps = kh * kw
    c_per_array = max(1, array_rows // taps)
    used_rows = c_per_array * taps
    k_tiles = int(math.ceil(c_in / c_per_array))
    tiling = ArrayTiling(
        k=k_tiles * used_rows,  # padded stretched length
        n=c_out,
        array_rows=used_rows,
        array_cols=array_cols,
        weight_bits=weight_bits,
        cell_bits=cell_bits,
    )
    return tiling, c_per_array
