"""CIM-oriented convolution framework (paper §III-C, Fig. 5).

The paper's engineering contribution: implementing column-wise weight and
partial-sum quantization for conv layers *without* per-array sequential
indexing or im2col linear ops. Two ideas, both reproduced natively:

1. **Stretched-kernel tiling.** Instead of im2col'ing activations and
   tiling the resulting matrix arbitrarily, choose the tiling stride so
   each CIM array holds ``c_per_array = floor(array_rows / K^2)`` whole
   input channels with all their K^2 taps ("stretched kernels remain
   intact in each array"). The array's MAC is then itself a convolution
   over a channel slice.

2. **Group convolution.** All ``k_tiles`` channel-slice convolutions run
   as ONE grouped convolution (``feature_group_count = k_tiles``) by
   replicating the activation channel-slices into groups — no sequential
   array indexing. The grouped conv's output channels factor as
   (k_tiles, C_out): exactly the per-array partial sums, ready for
   column-wise ADC quantization, fused dequant and shift-and-add.

Bit-splits are the leading axis of the grouped-conv weight batch, as in
Fig. 5's "weight duplication".

A third backend, ``deploy``, evaluates the same arithmetic through the
fused Pallas conv kernel (kernels/cim_conv) from ``repro.api.pack_conv``'s
packed int digit planes: stretched-kernel patches are extracted once (no
``n_split``x activation tiling) and ADC quantization happens per
array-tile accumulator in VMEM — the grouped-conv path's HBM partial-sum
round-trip disappears (DESIGN.md §3, §7).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.obs import adc as obs_adc

from .bitsplit import place_values, split_digits
from .cim_linear import CIMConfig, _deprecated, _quantize_act, deploy_act_codes
from .granularity import Granularity, conv_tiling
from .nibble import (can_pack_nibbles, is_nibble_packed, occupancy_map,
                     pack_nibbles)
from .quantizer import init_scale_from, lsq_fake_quant, qrange
from .variation import perturb_packed, variation_noise, variation_wanted


def _init_conv(
    key: jax.Array,
    kh: int, kw: int, c_in: int, c_out: int,
    cfg: CIMConfig,
    dtype=jnp.float32,
) -> Dict[str, jnp.ndarray]:
    """Params for a CIM conv layer; weight layout HWIO."""
    fan_in = kh * kw * c_in
    w = (jax.random.normal(key, (kh, kw, c_in, c_out), jnp.float32)
         * jnp.sqrt(2.0 / fan_in)).astype(dtype)
    params: Dict[str, jnp.ndarray] = {"w": w}
    if cfg.enabled:
        t, _ = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows,
                           cfg.array_cols, cfg.weight_bits, cfg.cell_bits)
        params["s_w"] = conv_weight_scales_from(w.astype(jnp.float32), cfg)
        _, qp_p = qrange(cfg.psum_bits, True)
        p_mag = jnp.sqrt(float(t.array_rows)) * (2 ** (cfg.act_bits - 2)) \
            * (2 ** (cfg.cell_bits - 1)) / 2.0
        params["s_p"] = jnp.full(
            t.psum_scale_shape(cfg.psum_granularity),
            2.0 * p_mag / jnp.sqrt(float(max(qp_p, 1))), jnp.float32)
        params["s_a"] = jnp.asarray([1.0], jnp.float32)
    return params


def conv_weight_scales_from(w: jnp.ndarray, cfg: CIMConfig) -> jnp.ndarray:
    """Per-group LSQ init for conv weights: a column group is one output
    channel's taps within one channel-slice array (paper's tiling)."""
    kh, kw, c_in, c_out = w.shape
    t, cpa = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows, cfg.array_cols,
                         cfg.weight_bits, cfg.cell_bits)
    _, qp = qrange(cfg.weight_bits, True)
    pad_c = t.k_tiles * cpa - c_in
    w_abs = jnp.abs(jnp.pad(w.astype(jnp.float32),
                            ((0, 0), (0, 0), (0, pad_c), (0, 0))))
    w_t = w_abs.reshape(kh * kw, t.k_tiles, cpa, c_out)
    ch = jnp.minimum(jnp.full((t.k_tiles,), cpa),
                     c_in - jnp.arange(t.k_tiles) * cpa).astype(jnp.float32)
    m_col = w_t.sum(axis=(0, 2)) / (ch[:, None] * kh * kw)     # (kt, c_out)
    g = cfg.weight_granularity
    if g == Granularity.COLUMN:
        s = m_col
    elif g == Granularity.ARRAY:
        pad_n = t.n_tiles * t.oc_per_array - c_out
        mc = jnp.pad(m_col, ((0, 0), (0, pad_n)))
        s = mc.reshape(t.k_tiles, t.n_tiles, t.oc_per_array).mean(-1)
    else:
        s = jnp.mean(m_col, keepdims=True).reshape(1, 1)
    return (2.0 * s / jnp.sqrt(float(max(qp, 1)))).astype(jnp.float32) + 1e-9


def _quantize_conv_weight_int(params, cfg: CIMConfig, t, c_per_array, kh, kw,
                              c_in, c_out):
    """Integer codes (kh, kw, c_in, c_out) with per-(array, column) scales."""
    w = params["w"].astype(jnp.float32)
    s_w = t.broadcast_weight_scale(params["s_w"])            # (kt, C_out)
    # expand scale to HWIO: channel c belongs to array tile c // c_per_array
    tile_of_c = jnp.arange(c_in) // c_per_array              # (c_in,)
    s_full = s_w[tile_of_c]                                  # (c_in, C_out)
    s_full = jnp.broadcast_to(s_full[None, None], (kh, kw, c_in, c_out))
    w_hat = lsq_fake_quant(
        w, s_full, cfg.weight_bits, signed=True,
        group_size=t.weight_group_size(cfg.weight_granularity))
    return w_hat / jnp.maximum(s_full, 1e-9)


def _conv_forward(
    x: jnp.ndarray,                      # (B, H, W, C_in)  NHWC
    params: Dict[str, jnp.ndarray],
    cfg: CIMConfig,
    *,
    stride: int = 1,
    padding: str = "SAME",
    variation_key: Optional[jax.Array] = None,
    variation_std=None,
    compute_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Conv2d through the CIM framework. Returns (B, H', W', C_out).

    ``cfg.mode`` resolves to a registered backend (repro.api.backends),
    mirroring the linear layer: ``off`` is a plain conv, ``emulate`` the
    paper-faithful QAT grouped-conv path, ``deploy`` packed-int inference
    through the fused Pallas conv kernel (from packed digit-plane
    params) — bit-exact with emulate, but the partial-sum tensor never
    reaches HBM and activations are not replicated ``n_split``x; ``ref``
    is the packed jnp oracle.

    ``variation_key``/``variation_std`` evaluate one Monte-Carlo device
    realization; noise is drawn in the packed 6-D layout on both modes,
    so emulate and deploy agree bit-exactly under a shared key
    (``variation_std=None`` falls back to ``cfg.variation_std``).
    """
    sigma = cfg.variation_std if variation_std is None else variation_std
    if not cfg.enabled:
        return _forward_conv_off(x, params, cfg, stride, padding,
                                 None, None, compute_dtype)
    from repro.api.backends import get_backend  # lazy: api builds on core
    return get_backend(cfg.mode).conv(x, params, cfg, stride, padding,
                                      variation_key, sigma, compute_dtype)


def _forward_conv_off(x, params, cfg, stride, padding, variation_key,
                      sigma, compute_dtype):
    return jax.lax.conv_general_dilated(
        x.astype(compute_dtype), params["w"].astype(compute_dtype),
        (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _forward_conv_emulate(x, params, cfg, stride, padding, variation_key,
                          sigma, compute_dtype):
    kh, kw, c_in, c_out = params["w"].shape
    dn = ("NHWC", "HWIO", "NHWC")
    t, c_per_array = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows,
                                 cfg.array_cols, cfg.weight_bits, cfg.cell_bits)
    k_tiles = t.k_tiles

    a_int, s_a = _quantize_act(x, params, cfg)               # (B,H,W,C_in)
    w_int = _quantize_conv_weight_int(params, cfg, t, c_per_array,
                                      kh, kw, c_in, c_out)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)  # (S,kh,kw,ci,co)
    n_split = digits.shape[0]

    # --- group-conv framework -------------------------------------------------
    # pad channels to k_tiles * c_per_array and replicate per group
    c_pad = k_tiles * c_per_array - c_in
    a_p = jnp.pad(a_int, ((0, 0), (0, 0), (0, 0), (0, c_pad)))
    d_p = jnp.pad(digits, ((0, 0), (0, 0), (0, 0), (0, c_pad), (0, 0)))

    # weights: (S, kh, kw, kt*cpa, co) -> grouped HWIO (kh, kw, cpa, S*kt*co)
    # group g in [0, S*kt): split s = g // kt, tile t = g % kt
    d_g = d_p.reshape(n_split, kh, kw, k_tiles, c_per_array, c_out)
    if variation_wanted(variation_key, sigma):
        # noise is drawn in the canonical PACKED layout (S, kt, kh, kw,
        # cpa, co) — the shape pack_deploy_conv stores — then transposed
        # into this path's grouping, so deploy sees identical theta per cell
        noise = variation_noise(
            variation_key, (n_split, k_tiles, kh, kw, c_per_array, c_out),
            sigma)
        d_g = d_g * jnp.transpose(noise, (0, 2, 3, 1, 4, 5))
    d_g = jnp.transpose(d_g, (1, 2, 4, 0, 3, 5))             # kh,kw,cpa,S,kt,co
    d_g = d_g.reshape(kh, kw, c_per_array, n_split * k_tiles * c_out)

    # activations: replicate the channel-slices once per split
    a_g = jnp.tile(a_p, (1, 1, 1, n_split))                  # (B,H,W,S*kt*cpa)

    psum = jax.lax.conv_general_dilated(
        a_g.astype(compute_dtype), d_g.astype(compute_dtype),
        (stride, stride), padding, dimension_numbers=dn,
        feature_group_count=n_split * k_tiles,
        preferred_element_type=jnp.float32,
    )                                                        # (B,H',W',S*kt*co)
    b, ho, wo, _ = psum.shape
    psum = psum.reshape(b, ho, wo, n_split, k_tiles, c_out)  # per-array psums

    if cfg.psum_quant:
        # psums are integer-valued (int x int MACs); snap float roundoff to
        # the grid so ADC tie-breaking matches the deploy kernel bit-exactly
        psum = psum + jax.lax.stop_gradient(jnp.round(psum) - psum)
        s_p = t.broadcast_psum_scale(params["s_p"])          # (S, kt, co)
        if obs_adc.enabled():
            # exact counters: emulate materializes every partial sum
            obs_adc.record(psum, s_p[None, None, None], cfg.psum_bits)
        psum = lsq_fake_quant(psum, s_p[None, None, None], cfg.psum_bits,
                              signed=True)

    # fused dequant + shift-and-add (paper Fig. 5 bottom)
    s_w = t.broadcast_weight_scale(params["s_w"])            # (kt, co)
    places = place_values(cfg.weight_bits, cfg.cell_bits)    # (S,)
    deq = places[:, None, None] * s_w[None]                  # (S, kt, co)
    y = jnp.einsum("bhwstc,stc->bhwc", psum.astype(jnp.float32), deq)
    y = y * jnp.maximum(s_a, 1e-9)
    return y.astype(compute_dtype)


def _forward_conv_deploy(x, params, cfg: CIMConfig, stride, padding,
                         variation_key, sigma, compute_dtype,
                         adc_free: bool = False):
    """Inference from packed conv digit planes (see ``_pack_conv``).

    The conv geometry (kh, kw, c_per_array) is carried statically by the
    6-D digit-plane shape, so packed params are self-describing under jit.
    Cell noise is injected by the kernel wrapper on the flattened packed
    planes (row-major identical to the 6-D layout) — the int planes are
    never re-packed per Monte-Carlo sample.

    When a mesh with a >1-device ``"model"`` axis is installed (see
    ``_forward_deploy``), the planes run column-sharded over C_out: every
    device extracts the same patches, evaluates its own output-channel
    shard, and one all-gather merges the activations (DESIGN.md §10).
    """
    from repro.kernels import ops as kops  # lazy: avoids import cycle
    from repro.nn.module import current_mesh

    d6 = params["w_digits"]              # (S, kt, kh, kw, cpa, C_out)
    n_split, k_tiles, kh, kw, cpa_stored, c_out = d6.shape
    # uint8 planes are nibble-packed along cpa (repro.core.nibble): the
    # stored channel-slice axis holds half the logical rows
    c_per_array = 2 * cpa_stored if is_nibble_packed(d6) else cpa_stored
    digits = d6.reshape(n_split, k_tiles, kh * kw * cpa_stored, c_out)
    if not variation_wanted(variation_key, sigma):
        variation_key = sigma = None

    s_a = params["s_a"]
    a_int = deploy_act_codes(x, s_a, cfg)

    # logical geometry from the activation; must match the packed planes
    c_in = x.shape[-1]
    t, cpa = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows, cfg.array_cols,
                         cfg.weight_bits, cfg.cell_bits)
    assert (t.k_tiles, cpa) == (k_tiles, c_per_array), (
        f"packed digit planes {d6.shape} were built for a different "
        f"geometry than x/cfg imply: expected (k_tiles, c_per_array)="
        f"{(t.k_tiles, cpa)}, packed {(k_tiles, c_per_array)}")

    s_p = t.broadcast_psum_scale(params["s_p"])              # (S, kt, co)
    s_w = t.broadcast_weight_scale(params["s_w"])            # (kt, co)
    places = place_values(cfg.weight_bits, cfg.cell_bits)    # (S,)
    deq = places[:, None, None] * s_w[None] * jnp.maximum(s_a, 1e-9)
    if "deq_scale" in params:
        # in-service recalibration correction (eval/recalibrate.py): a
        # per-column dequant gain shipped as a ScaleDelta, (S, kt, co)
        deq = deq * params["deq_scale"]

    y = kops.cim_conv(
        a_int, digits, s_p, deq,
        kh=kh, kw=kw, stride=stride, padding=padding,
        c_per_array=c_per_array,
        psum_bits=cfg.psum_bits, psum_quant=cfg.psum_quant,
        use_kernel=cfg.use_kernel,
        variation_key=variation_key, variation_std=sigma,
        mesh=current_mesh(), adc_free=adc_free,
        occ=params.get("w_occ"),
    )
    return y.astype(compute_dtype)


def _pack_conv(params: Dict[str, jnp.ndarray], cfg: CIMConfig, *,
               variation_key: Optional[jax.Array] = None,
               variation_std=None) -> Dict[str, jnp.ndarray]:
    """Convert trained emulate-mode conv params to the packed deploy form.

    Digit planes are stored 6-D — (S, k_tiles, kh, kw, c_per_array, C_out)
    — i.e. HWIO grouped by channel slice, row order (dh, dw, c) matching
    ``ref.extract_conv_patches``. The shape carries the conv geometry, so
    the deploy forward needs no side-channel metadata. pack_dtype='int4'
    stores each plane as int4 (sign-magnitude digits of <=3-bit cells fit
    [-7, 7]) — halves weight HBM vs int8.

    ``variation_key``/``variation_std`` bake ONE log-normal device
    realization into the planes (float32); for Monte-Carlo sweeps keep
    the planes clean and use ``perturb_packed``/the forward's
    ``variation_key`` instead (no re-packing per sample).

    Layout v4 extras (DESIGN.md §14): ``w_occ`` — per-(split, array tile,
    output channel) uint8 occupancy over the (kh, kw, cpa) cell block —
    and, for ``pack_dtype='int4'`` with an even ``c_per_array``,
    half-split nibble packing of the cpa axis (two digits per uint8
    byte, ``repro.core.nibble``)."""
    kh, kw, c_in, c_out = params["w"].shape
    t, cpa = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows, cfg.array_cols,
                         cfg.weight_bits, cfg.cell_bits)
    w_int = _quantize_conv_weight_int(params, cfg, t, cpa, kh, kw,
                                      c_in, c_out)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)
    n_split = digits.shape[0]
    c_pad = t.k_tiles * cpa - c_in
    d = jnp.pad(digits, ((0, 0), (0, 0), (0, 0), (0, c_pad), (0, 0)))
    d = d.reshape(n_split, kh, kw, t.k_tiles, cpa, c_out)
    d = jnp.transpose(d, (0, 3, 1, 2, 4, 5))     # (S, kt, kh, kw, cpa, co)
    d = d.astype(cfg.store_dtype())
    occ = occupancy_map(d, conv=True)
    if can_pack_nibbles(cpa, cfg.store_dtype()):
        d = pack_nibbles(d)                      # cpa axis, two per byte
    out = {
        "w_digits": d,
        "w_occ": occ,
        "s_w": params["s_w"],
        "s_p": params["s_p"],
        "s_a": params["s_a"],
    }
    if variation_wanted(variation_key, variation_std):
        out = perturb_packed(out, variation_key, variation_std)
    return out


def _calibrate_conv(x, params, cfg: CIMConfig, *, stride: int = 1,
                    padding: str = "SAME") -> Dict[str, jnp.ndarray]:
    """One-batch LSQ-style calibration of s_a and s_p for a conv layer."""
    if not cfg.enabled:
        return params
    kh, kw, c_in, c_out = params["w"].shape
    t, c_per_array = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows,
                                 cfg.array_cols, cfg.weight_bits, cfg.cell_bits)
    p = dict(params)
    _, qp_a = qrange(cfg.act_bits, cfg.act_signed)
    p["s_a"] = (2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(float(max(qp_a, 1)))
                ).reshape(1).astype(jnp.float32) + 1e-9

    a_int, _ = _quantize_act(x, p, cfg)
    w_int = _quantize_conv_weight_int(p, cfg, t, c_per_array, kh, kw, c_in, c_out)
    digits = split_digits(w_int, cfg.weight_bits, cfg.cell_bits)
    n_split = digits.shape[0]
    k_tiles = t.k_tiles
    c_pad = k_tiles * c_per_array - c_in
    a_p = jnp.pad(a_int, ((0, 0), (0, 0), (0, 0), (0, c_pad)))
    d_p = jnp.pad(digits, ((0, 0), (0, 0), (0, 0), (0, c_pad), (0, 0)))
    d_g = d_p.reshape(n_split, kh, kw, k_tiles, c_per_array, c_out)
    d_g = jnp.transpose(d_g, (1, 2, 4, 0, 3, 5)).reshape(
        kh, kw, c_per_array, n_split * k_tiles * c_out)
    a_g = jnp.tile(a_p, (1, 1, 1, n_split))
    psum = jax.lax.conv_general_dilated(
        a_g.astype(jnp.float32), d_g.astype(jnp.float32), (stride, stride),
        padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=n_split * k_tiles)
    b, ho, wo, _ = psum.shape
    psum = psum.reshape(-1, n_split, k_tiles, c_out)
    mean_abs = jnp.mean(jnp.abs(psum), axis=0)               # (S, kt, co)
    _, qp_p = qrange(cfg.psum_bits, True)
    pg = cfg.psum_granularity
    if pg == Granularity.LAYER:
        s = jnp.mean(mean_abs, axis=(1, 2), keepdims=True)
    elif pg == Granularity.ARRAY:
        pad_n = t.n_tiles * t.oc_per_array - t.n
        ma = jnp.pad(mean_abs, ((0, 0), (0, 0), (0, pad_n)))
        s = jnp.mean(ma.reshape(t.n_split, t.k_tiles, t.n_tiles,
                                t.oc_per_array), axis=-1)
    else:
        s = mean_abs
    p["s_p"] = (2.0 * s / jnp.sqrt(float(max(qp_p, 1)))).astype(jnp.float32) + 1e-9
    return p


def conv_dequant_muls(params, cfg: CIMConfig) -> int:
    """Paper Fig. 8 x-axis: dequant scale multiplications for this layer."""
    kh, kw, c_in, c_out = params["w"].shape
    t, _ = conv_tiling(kh, kw, c_in, c_out, cfg.array_rows, cfg.array_cols,
                       cfg.weight_bits, cfg.cell_bits)
    return t.dequant_muls(cfg.weight_granularity, cfg.psum_granularity)


# ---------------------------------------------------------------------------
# deprecated entry points (pre-`repro.api` surface)
# ---------------------------------------------------------------------------

def init_cim_conv(*args, **kw) -> Dict[str, jnp.ndarray]:
    """Deprecated: use ``repro.api.init_conv`` / ``QuantConv2d.init``."""
    _deprecated("init_cim_conv", "repro.api.init_conv")
    return _init_conv(*args, **kw)


def cim_conv2d(*args, **kw) -> jnp.ndarray:
    """Deprecated: use ``repro.api.conv2d`` / ``QuantConv2d.__call__``."""
    _deprecated("cim_conv2d", "repro.api.conv2d")
    return _conv_forward(*args, **kw)


def calibrate_cim_conv(*args, **kw) -> Dict[str, jnp.ndarray]:
    """Deprecated: use ``repro.api.calibrate_conv``."""
    _deprecated("calibrate_cim_conv", "repro.api.calibrate_conv")
    return _calibrate_conv(*args, **kw)


def pack_deploy_conv(*args, **kw) -> Dict[str, jnp.ndarray]:
    """Deprecated: use ``repro.api.pack_conv`` / ``QuantConv2d.pack``
    (which returns a versioned, saveable ``DeployArtifact``)."""
    _deprecated("pack_deploy_conv", "repro.api.pack_conv")
    return _pack_conv(*args, **kw)
