"""Memory-cell variation model (paper §IV-E, Eq. 5).

Device non-idealities are modeled as multiplicative log-normal noise on the
stored cell conductances: d_var = d * exp(theta), theta ~ N(0, sigma^2).
The noise is applied to the *bit-split cell values* (each physical cell
drifts independently), which is where real RRAM variation acts.

Bit-exactness contract (DESIGN.md §8): noise is always drawn in the
**packed digit-plane layout** — ``(S, k_tiles, rows, N)`` for linear,
``(S, k_tiles, kh, kw, c_per_array, C_out)`` for conv — because that is
the one layout both execution paths share: the deploy path stores digit
planes packed, and the emulate path tiles/groups its digits into the same
element order before the MAC. Drawing ``jax.random.normal`` over the
packed shape therefore assigns *the same theta to the same physical cell*
on both paths, which is what makes deploy and emulate agree bit-exactly
under a shared ``variation_key``. (``jax.random.normal`` fills row-major,
so the flattened conv layout ``(S, kt, kh*kw*cpa, C_out)`` draws identical
values to the 6-D packed layout.)

``sigma`` may be a Python float or a traced JAX scalar. Tracing sigma lets
a Monte-Carlo sweep jit one evaluation function and feed the whole sigma
grid as data — no recompile per noise level. The zero-noise fast path
(skip the normal draw entirely) applies only when sigma is a *static*
Python number <= 0 or the key is None.
"""
from __future__ import annotations

from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp

Sigma = Union[float, jnp.ndarray]


def is_static_zero(sigma: Optional[Sigma]) -> bool:
    """True when sigma is statically known to disable variation."""
    return sigma is None or (isinstance(sigma, (int, float)) and sigma <= 0.0)


def variation_wanted(key: Optional[jax.Array], sigma: Optional[Sigma]) -> bool:
    """The single trace-time gate both paths use: noise is injected iff a
    key is given and sigma is not statically zero."""
    return key is not None and not is_static_zero(sigma)


def variation_noise(key: jax.Array, shape, sigma: Sigma) -> jnp.ndarray:
    """Multiplicative log-normal factor exp(sigma * N(0, 1)), float32."""
    theta = jax.random.normal(key, shape, dtype=jnp.float32)
    return jnp.exp(jnp.asarray(sigma, jnp.float32) * theta)


def apply_cell_variation(
    digits: jnp.ndarray, key: jax.Array, sigma: Sigma
) -> jnp.ndarray:
    """Perturb cell values: d -> d * exp(theta), theta ~ N(0, sigma)."""
    if is_static_zero(sigma):
        return digits
    noisy = digits.astype(jnp.float32) * variation_noise(key, digits.shape,
                                                         sigma)
    return noisy.astype(digits.dtype)


def perturb_digits(digits: jnp.ndarray, key: jax.Array,
                   sigma: Sigma) -> jnp.ndarray:
    """Perturb digit planes *in their packed layout*; returns float32.

    Unlike ``apply_cell_variation`` this never casts back to the input
    dtype: noisy conductances are not integers, and rounding them back to
    int8/int4 storage would quantize the very non-ideality being modeled.
    The deploy kernels accept float digit operands (they upcast to f32 in
    VMEM regardless).
    """
    if is_static_zero(sigma):
        return digits.astype(jnp.float32)
    return digits.astype(jnp.float32) * variation_noise(key, digits.shape,
                                                        sigma)


def perturb_packed(packed: Dict[str, jnp.ndarray], key: jax.Array,
                   sigma: Sigma, *, sample: Optional[int] = None
                   ) -> Dict[str, jnp.ndarray]:
    """One Monte-Carlo device realization of packed deploy params.

    Returns a new packed dict whose ``w_digits`` planes carry log-normal
    conductance noise (float32); scales and metadata pass through, and the
    int planes are never re-packed — sampling N devices costs N cheap
    elementwise perturbations of the same packed tensor. ``sample`` folds
    a Monte-Carlo sample index into ``key`` (``jax.random.fold_in``), so a
    sweep is keyed by one base key + sample number. Works for linear
    (4-D) and conv (6-D) packed planes alike.
    """
    if sample is not None:
        key = jax.random.fold_in(key, sample)
    out = dict(packed)
    out["w_digits"] = perturb_digits(packed["w_digits"], key, sigma)
    return out
