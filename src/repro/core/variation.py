"""Memory-cell variation model (paper §IV-E, Eq. 5).

Device non-idealities are modeled as multiplicative log-normal noise on the
stored cell conductances: d_var = d * exp(theta), theta ~ N(0, sigma^2).
The noise is applied to the *bit-split cell values* (each physical cell
drifts independently), which is where real RRAM variation acts.

Bit-exactness contract (DESIGN.md §8): noise is always drawn in the
**packed digit-plane layout** — ``(S, k_tiles, rows, N)`` for linear,
``(S, k_tiles, kh, kw, c_per_array, C_out)`` for conv — because that is
the one layout both execution paths share: the deploy path stores digit
planes packed, and the emulate path tiles/groups its digits into the same
element order before the MAC. Drawing ``jax.random.normal`` over the
packed shape therefore assigns *the same theta to the same physical cell*
on both paths, which is what makes deploy and emulate agree bit-exactly
under a shared ``variation_key``. (``jax.random.normal`` fills row-major,
so the flattened conv layout ``(S, kt, kh*kw*cpa, C_out)`` draws identical
values to the 6-D packed layout.)

``sigma`` may be a Python float or a traced JAX scalar. Tracing sigma lets
a Monte-Carlo sweep jit one evaluation function and feed the whole sigma
grid as data — no recompile per noise level. The zero-noise fast path
(skip the normal draw entirely) applies only when sigma is a *static*
Python number <= 0 or the key is None.

Temporal drift (DESIGN.md §11): ``sigma`` may also be a ``DriftState`` —
a ``DriftSchedule`` (static rates) plus a request-count clock ``t``
(traced leaf). Everywhere a sigma flows (the forward arguments, the
kernel wrappers, ``perturb_packed``) a DriftState flows identically;
``variation_noise`` dispatches on the type, so the one bit-exactness
contract above covers drift too: the drift field is drawn in the packed
layout from the shared key, and emulate/deploy/sharded agree bit-exactly
at every ``t``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp

Sigma = Union[float, jnp.ndarray, "DriftState"]

# key-derivation tags for the independent drift field components
_READ_TAG = 0x0D1F7001
_CELL_TAG = 0x0D1F7002
_COL_TAG = 0x0D1F7003


@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """Sigma schedule of a time-indexed drift process, indexed by the
    request count ``t`` (decode steps served). Three independent
    log-normal components compose multiplicatively on the cell
    conductances (all sigmas in log-space, like ``variation_std``):

      read      transient read noise, resampled every request:
                sigma_read(t) = read_sigma + read_rate * t (aging makes
                reads noisier); theta re-drawn per t.
      cell      persistent per-cell bias that accumulates with use:
                sigma_cell(t) = cell_rate * t; theta frozen per cell —
                the same realization at every t, only its magnitude
                grows. This is what retention/endurance drift looks like.
      column    persistent per-*column* gain drift, sigma_col(t) =
                col_rate * t; one theta per physical array column
                (split, k_tile, column), shared by every cell on the
                bitline — shared read-path/ADC-reference aging. This is
                the component the paper's column-wise scale factors can
                absorb exactly, and what in-service recalibration re-fits
                (eval/recalibrate.py) without touching digit planes.
    """

    read_sigma: float = 0.0
    read_rate: float = 0.0
    cell_rate: float = 0.0
    col_rate: float = 0.0

    @property
    def is_static_zero(self) -> bool:
        return (self.read_sigma <= 0.0 and self.read_rate <= 0.0
                and self.cell_rate <= 0.0 and self.col_rate <= 0.0)

    def at(self, t) -> "DriftState":
        return DriftState(schedule=self, t=jnp.asarray(t, jnp.int32))


@dataclasses.dataclass
class DriftState:
    """A DriftSchedule evaluated at request count ``t``. Registered as a
    pytree with ``t`` as the (traceable) leaf and the schedule as static
    aux data, so a jitted forward can sweep t — or advance the serving
    clock — with zero recompiles. Pass it wherever a ``variation_std``
    sigma is accepted."""

    schedule: DriftSchedule
    t: jnp.ndarray


jax.tree_util.register_pytree_node(
    DriftState,
    lambda d: ((d.t,), d.schedule),
    lambda sched, leaves: DriftState(schedule=sched, t=leaves[0]),
)


def _column_field_shape(shape) -> tuple:
    """The per-column broadcast shape for a packed digit-plane shape:
    row dims collapse to 1, one theta per (split, k_tile, column).
    Packed layouts are (S, kt, rows..., N) — linear 4-D, conv 6-D — with
    an optional leading layer axis for the stacked scan-over-layers
    forms (5-D / 7-D)."""
    lead = 1 if len(shape) in (5, 7) else 0
    return (tuple(shape[:lead + 2]) + (1,) * (len(shape) - lead - 3)
            + (shape[-1],))


def drift_field(key: jax.Array, shape, state: DriftState) -> jnp.ndarray:
    """Multiplicative drift factor over a packed digit-plane shape at
    request count ``state.t``: exp of the sum of the active components'
    log-fields. Persistent components (cell, column) draw their theta
    from t-independent keys — the realization is frozen, only its
    magnitude grows — while the read component folds ``t`` into its key
    and resamples every request. Statically-zero components skip their
    draw entirely, so a column-only schedule never materializes a
    full-plane normal."""
    sch = state.schedule
    tf = jnp.asarray(state.t, jnp.float32)
    log_f = jnp.zeros((1,) * len(shape), jnp.float32)
    if sch.read_sigma > 0.0 or sch.read_rate > 0.0:
        k_read = jax.random.fold_in(jax.random.fold_in(key, _READ_TAG),
                                    jnp.asarray(state.t, jnp.int32))
        log_f = log_f + ((sch.read_sigma + sch.read_rate * tf)
                         * jax.random.normal(k_read, shape, jnp.float32))
    if sch.cell_rate > 0.0:
        k_cell = jax.random.fold_in(key, _CELL_TAG)
        log_f = log_f + ((sch.cell_rate * tf)
                         * jax.random.normal(k_cell, shape, jnp.float32))
    if sch.col_rate > 0.0:
        k_col = jax.random.fold_in(key, _COL_TAG)
        theta_col = jax.random.normal(k_col, _column_field_shape(shape),
                                      jnp.float32)
        log_f = log_f + (sch.col_rate * tf) * theta_col
    return jnp.exp(log_f)


def is_static_zero(sigma: Optional[Sigma]) -> bool:
    """True when sigma is statically known to disable variation."""
    if isinstance(sigma, DriftState):
        return sigma.schedule.is_static_zero
    return sigma is None or (isinstance(sigma, (int, float)) and sigma <= 0.0)


def variation_wanted(key: Optional[jax.Array], sigma: Optional[Sigma]) -> bool:
    """The single trace-time gate both paths use: noise is injected iff a
    key is given and sigma is not statically zero."""
    return key is not None and not is_static_zero(sigma)


def variation_noise(key: jax.Array, shape, sigma: Sigma) -> jnp.ndarray:
    """Multiplicative log-normal factor exp(sigma * N(0, 1)), float32.
    When ``sigma`` is a ``DriftState`` the factor is the composed drift
    field at its request count instead (see ``drift_field``); the result
    broadcasts against ``shape``."""
    if isinstance(sigma, DriftState):
        return drift_field(key, shape, sigma)
    theta = jax.random.normal(key, shape, dtype=jnp.float32)
    return jnp.exp(jnp.asarray(sigma, jnp.float32) * theta)


def apply_cell_variation(
    digits: jnp.ndarray, key: jax.Array, sigma: Sigma
) -> jnp.ndarray:
    """Perturb cell values: d -> d * exp(theta), theta ~ N(0, sigma)."""
    if is_static_zero(sigma):
        return digits
    noisy = digits.astype(jnp.float32) * variation_noise(key, digits.shape,
                                                         sigma)
    return noisy.astype(digits.dtype)


def perturb_digits(digits: jnp.ndarray, key: jax.Array,
                   sigma: Sigma) -> jnp.ndarray:
    """Perturb digit planes *in their packed layout*; returns float32.

    Unlike ``apply_cell_variation`` this never casts back to the input
    dtype: noisy conductances are not integers, and rounding them back to
    int8/int4 storage would quantize the very non-ideality being modeled.
    The deploy kernels accept float digit operands (they upcast to f32 in
    VMEM regardless).
    """
    if is_static_zero(sigma):
        return digits.astype(jnp.float32)
    return digits.astype(jnp.float32) * variation_noise(key, digits.shape,
                                                        sigma)


def perturb_packed(packed: Dict[str, jnp.ndarray], key: jax.Array,
                   sigma: Sigma, *, sample: Optional[int] = None
                   ) -> Dict[str, jnp.ndarray]:
    """One Monte-Carlo device realization of packed deploy params.

    Returns a new packed dict whose ``w_digits`` planes carry log-normal
    conductance noise (float32); scales and metadata pass through, and the
    int planes are never re-packed — sampling N devices costs N cheap
    elementwise perturbations of the same packed tensor. ``sample`` folds
    a Monte-Carlo sample index into ``key`` (``jax.random.fold_in``), so a
    sweep is keyed by one base key + sample number. Works for linear
    (4-D) and conv (6-D) packed planes alike.

    Nibble-packed (uint8) planes are decoded to their logical layout
    first — the noise contract (DESIGN.md §8) draws over the LOGICAL
    plane shape, so a nibble-packed and a dense artifact perturb the
    same physical cell from the same key. Any ``w_occ`` map passes
    through unchanged: multiplicative noise keeps zero cells zero, so
    clean-digit occupancy stays valid for every realization.
    """
    if sample is not None:
        key = jax.random.fold_in(key, sample)
    out = dict(packed)
    d = packed["w_digits"]
    if jnp.dtype(d.dtype) == jnp.dtype(jnp.uint8):
        from .nibble import unpack_nibbles  # lazy: keeps module load light
        d = unpack_nibbles(d)
    out["w_digits"] = perturb_digits(d, key, sigma)
    return out


# ---------------------------------------------------------------------------
# whole-tree drift injection (the serving engine's chip model)
# ---------------------------------------------------------------------------

def path_fold_key(key: jax.Array, path) -> jax.Array:
    """Derive a per-node key from a tree path (tuple of parts), stable
    under tree growth — the same hash ``repro.api.pack_model`` folds for
    per-layer variation baking, exported so drift injection and scale-
    delta fitting key nodes identically across processes."""
    h = 0
    for part in path:
        for ch in str(part):
            h = (h * 131 + ord(ch)) % (2 ** 31 - 1)
        h = (h * 131 + 7) % (2 ** 31 - 1)
    return jax.random.fold_in(key, h)


def drift_tree(params, key: jax.Array, state: DriftState):
    """One chip realization of a whole packed model tree at request count
    ``state.t``: every packed CIM node's ``w_digits`` planes are
    perturbed by the drift field (float32), keyed per node by
    ``path_fold_key`` — scales, metadata and full-precision nodes pass
    through untouched, and the int planes are never re-packed. Works on
    linear/conv nodes and their stacked scan-over-layers forms alike
    (the field's column component reads the layout from the plane rank).

    Deterministic in (key, t, tree paths): the same call under a column-
    sharded mesh draws the same field values, so sharded and unsharded
    serving drift bit-identically (tests assert)."""
    if is_static_zero(state):
        return params

    def walk(node, path):
        if isinstance(node, dict):
            if "w_digits" in node:
                return perturb_packed(node, path_fold_key(key, path), state)
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, path + (str(i),)) for i, v in enumerate(node)]
        return node
    return walk(params, ())
