"""Memory-cell variation model (paper §IV-E, Eq. 5).

Device non-idealities are modeled as multiplicative log-normal noise on the
stored cell conductances: w_var = w * exp(theta), theta ~ N(0, sigma^2).
The noise is applied to the *bit-split cell values* (each physical cell
drifts independently), which is where real RRAM variation acts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_cell_variation(
    digits: jnp.ndarray, key: jax.Array, sigma: float
) -> jnp.ndarray:
    """Perturb cell values: d -> d * exp(theta), theta ~ N(0, sigma)."""
    if sigma <= 0.0:
        return digits
    theta = sigma * jax.random.normal(key, digits.shape, dtype=jnp.float32)
    return (digits.astype(jnp.float32) * jnp.exp(theta)).astype(digits.dtype)
