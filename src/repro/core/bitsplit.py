"""Bit-splitting of integer weights across multi-bit memory cells.

A b-bit signed integer weight is stored across ``n_split = ceil(b/c)``
cells of c bits each (paper Fig. 5: "weight duplication and quantization
into bit-splits"). We use **differential sign-magnitude** encoding, the
RRAM-faithful scheme (conductances are non-negative; positive/negative
weights live on a G+/G- column pair whose analog difference feeds the
ADC — the paper's variation reference [11] models exactly such cells):

    w_int = sign(w_int) * sum_s d_s * 2^(c*s),  d_s = digit_s(|w_int|)

Each physical cell stores an unsigned digit in [0, 2^c); the sign is the
pair assignment. Collapsing the pair, the effective digit seen by the MAC
is sign(w) * d_s, so small weights have small stored digits — which is
what makes multiplicative (log-normal) cell variation benign for small
weights, unlike two's-complement encodings that represent small negative
values with large complementary digit pairs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .granularity import n_splits


def split_digits(w_int: jnp.ndarray, weight_bits: int, cell_bits: int) -> jnp.ndarray:
    """Decompose integer-valued ``w_int`` (float dtype ok) into signed-
    magnitude digits, shape (n_split,) + w_int.shape, digit s having place
    value 2**(cell_bits*s). STE: the gradient w.r.t. w_int distributes
    across digits by place value (recombine(grad) == grad)."""
    if weight_bits == 1:
        # binary weights {-1, +1}: one signed cell holds the value directly
        return w_int[None]
    s_count = n_splits(weight_bits, cell_bits)
    base = 2 ** cell_bits
    w = jax.lax.stop_gradient(w_int)
    sign = jnp.sign(w)
    mag = jnp.abs(w).astype(jnp.int32)
    digits = []
    for s in range(s_count):
        digits.append(((mag // (base ** s)) % base).astype(w_int.dtype) * sign)
    out = jnp.stack(digits, axis=0)
    # STE: least-norm distribution of the incoming gradient over digits
    places = place_values(weight_bits, cell_bits).astype(w_int.dtype)
    norm = jnp.sum(places ** 2)
    corr = (w_int - jax.lax.stop_gradient(w_int))  # zero-valued, carries grad
    out = out + corr[None, ...] * (places / norm).reshape(
        (s_count,) + (1,) * w_int.ndim)
    return out


def place_values(weight_bits: int, cell_bits: int) -> jnp.ndarray:
    s_count = n_splits(weight_bits, cell_bits)
    return jnp.asarray([2.0 ** (cell_bits * s) for s in range(s_count)],
                       jnp.float32)


def recombine(digits: jnp.ndarray, weight_bits: int, cell_bits: int) -> jnp.ndarray:
    places = place_values(weight_bits, cell_bits).astype(digits.dtype)
    return jnp.tensordot(places, digits, axes=(0, 0))
