"""Learned Step Size Quantization (LSQ, Esser et al. 2020) at arbitrary
granularity, as extended by the paper (§III-A) to column-wise scales for
both weights and partial sums.

All quantizers are fake-quant: they return float tensors whose values lie
on the integer grid times the (learnable) scale. Gradients follow LSQ:

  dy/dx = 1                      inside the clip range, 0 outside
  dy/ds = -x/s + round(x/s)      inside the clip range
        = q_n or q_p             outside
  with the scale gradient multiplied by g = 1/sqrt(N_group * q_p).

``bits == 1`` is binary sign quantization (Saxena'22-style ADC-less
partial sums): y = sign(x) * s with an STE through the sign.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-9


def qrange(bits: int, signed: bool = True) -> Tuple[int, int]:
    if bits == 1:
        return (-1, 1)
    if signed:
        return (-(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
    return (0, 2 ** bits - 1)


def round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """Round with straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


@jax.custom_vjp
def _lsq(x, s, qn, qp, g):
    s = jnp.maximum(s, _EPS)
    return jnp.clip(jnp.round(x / s), qn, qp) * s


def _lsq_fwd(x, s, qn, qp, g):
    s = jnp.maximum(s, _EPS)
    v = x / s
    return jnp.clip(jnp.round(v), qn, qp) * s, (v, s, qn, qp, g)


def _lsq_bwd(res, dy):
    v, s, qn, qp, g = res
    lower = v <= qn
    upper = v >= qp
    mid = jnp.logical_not(jnp.logical_or(lower, upper))
    dx = jnp.where(mid, dy, 0.0)
    ds_elem = jnp.where(mid, jnp.round(v) - v, jnp.where(lower, qn, qp))
    ds_full = dy * ds_elem * g
    # reduce to the scale's (broadcasted-from) shape
    ds = _reduce_to_shape(ds_full, s.shape)
    return dx, ds, None, None, None


def _reduce_to_shape(t: jnp.ndarray, shape) -> jnp.ndarray:
    if t.shape == tuple(shape):
        return t
    # sum over leading extra dims
    while t.ndim > len(shape):
        t = t.sum(axis=0)
    axes = tuple(i for i, (a, b) in enumerate(zip(t.shape, shape)) if b == 1 and a != 1)
    if axes:
        t = t.sum(axis=axes, keepdims=True)
    return t.reshape(shape)


_lsq.defvjp(_lsq_fwd, _lsq_bwd)


@jax.custom_vjp
def _lsq_binary(x, s, g):
    s = jnp.maximum(s, _EPS)
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype) * s


def _lsq_binary_fwd(x, s, g):
    s = jnp.maximum(s, _EPS)
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype) * s, (x, s, g)


def _lsq_binary_bwd(res, dy):
    x, s, g = res
    # STE with clipping window |x| <= s (hard-tanh style)
    mid = jnp.abs(x) <= s
    dx = jnp.where(mid, dy, 0.0)
    sign = jnp.where(x >= 0, 1.0, -1.0)
    ds = _reduce_to_shape(dy * sign * g, s.shape)
    return dx, ds, None


_lsq_binary.defvjp(_lsq_binary_fwd, _lsq_binary_bwd)


def lsq_fake_quant(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int,
    *,
    signed: bool = True,
    group_size: int | None = None,
) -> jnp.ndarray:
    """Fake-quantize ``x`` with learnable ``scale`` (broadcastable to x)."""
    qn, qp = qrange(bits, signed)
    n = group_size if group_size is not None else max(1, x.size // max(1, scale.size))
    g = 1.0 / jnp.sqrt(float(n) * float(max(qp, 1)))
    if bits == 1:
        return _lsq_binary(x, scale, g)
    return _lsq(x, scale, float(qn), float(qp), g)


def lsq_integer(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int,
    *,
    signed: bool = True,
    group_size: int | None = None,
) -> jnp.ndarray:
    """Return the *integer* code (float dtype, integer valued) with LSQ
    gradients flowing to both ``x`` and ``scale``: equals
    ``lsq_fake_quant(x, s, ...) / s`` computed stably."""
    s = jnp.maximum(scale, _EPS)
    return lsq_fake_quant(x, scale, bits, signed=signed, group_size=group_size) / s


def init_scale_from(x: jnp.ndarray, bits: int, axes, shape) -> jnp.ndarray:
    """LSQ initialization: s = 2 * E|x| / sqrt(q_p), per group."""
    _, qp = qrange(bits, True)
    m = jnp.mean(jnp.abs(x), axis=axes)
    s = 2.0 * m / jnp.sqrt(float(max(qp, 1)))
    if s.ndim == 0:
        return jnp.full(shape, s, jnp.float32) + _EPS
    return jnp.broadcast_to(s.reshape(shape), shape).astype(jnp.float32) + _EPS
