import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Roofline ledger: for every (arch x shape) cell,
  1. production dry-run on the (16,16) pod mesh  -> memory fit + schedule,
  2. production dry-run on the (2,16,16) multi-pod mesh -> compile proof,
  3. loop-corrected accounting (launch/account.py) -> exact flops / bytes /
     collective bytes per device,
and derive the three roofline terms. Incremental JSON (resumable):

  PYTHONPATH=src python -m repro.launch.ledger --out results/ledger.json
"""
import argparse
import json
import sys
import time
import traceback

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except Exception:
        return {}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/ledger.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated arch filter")
    ap.add_argument("--skip-multipod", action="store_true")
    ap.add_argument("--skip-account", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS, cell_status
    from repro.launch.account import account_cell
    from repro.launch.dryrun import model_flops, run_cell
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    ledger = load(args.out)
    archs = list(ARCHS)
    if args.only:
        archs = [a for a in archs if a in args.only.split(",")]

    mesh1 = make_production_mesh(multi_pod=False)

    for arch in archs:
        for shape in SHAPES:
            key = f"{arch}|{shape}"
            if key in ledger and ledger[key].get("status") in ("ok", "skipped"):
                continue
            ok, why = cell_status(arch, shape)
            if not ok:
                ledger[key] = {"status": "skipped", "reason": why}
                _save(args.out, ledger)
                print(f"[ledger] {key}: SKIP ({why})", flush=True)
                continue
            rec = {"status": "ok"}
            t0 = time.time()
            try:
                prod = run_cell(arch, shape, multi_pod=False, verbose=False)
                rec["production"] = {k: prod[k] for k in
                                     ("per_device", "collectives",
                                      "lower_s", "compile_s", "kind",
                                      "chips")}
            except Exception as e:
                traceback.print_exc()
                rec = {"status": "error", "stage": "production",
                       "error": f"{type(e).__name__}: {e}"}
                ledger[key] = rec
                _save(args.out, ledger)
                continue
            if not args.skip_multipod:
                try:
                    t1 = time.time()
                    mp = run_cell(arch, shape, multi_pod=True, verbose=False)
                    rec["multipod"] = {
                        "compile_s": mp["compile_s"],
                        "peak_gb": mp["per_device"][
                            "bytes_per_device_peak"] / 1e9,
                        "collective_bytes": mp["per_device"][
                            "collective_bytes"],
                    }
                except Exception as e:
                    traceback.print_exc()
                    rec["multipod"] = {"status": "error",
                                       "error": f"{type(e).__name__}: {e}"}
            if not args.skip_account:
                try:
                    acct = account_cell(arch, shape, mesh1, verbose=False)
                    rec["account"] = acct
                except Exception as e:
                    traceback.print_exc()
                    rec["account"] = {"status": "error",
                                      "error": f"{type(e).__name__}: {e}"}

            # roofline terms from the corrected accounting (fallback:
            # production aggregates, which undercount loop bodies)
            src = rec.get("account") if "hlo_flops" in rec.get("account", {}) \
                else rec["production"]["per_device"]
            cell = build_cell(arch, shape, mesh1)
            mf = model_flops(cell)
            chips = 256
            terms = {
                "compute_s": src["hlo_flops"] / PEAK_FLOPS,
                "memory_s": src["hlo_bytes"] / HBM_BW,
                "collective_s": src["collective_bytes"] / ICI_BW,
            }
            dom = max(terms, key=terms.get)
            rec["roofline"] = {
                **terms,
                "dominant": dom,
                "model_flops_global": mf,
                "useful_ratio": (mf / chips) / max(src["hlo_flops"], 1.0),
                "peak_hbm_gb": rec["production"]["per_device"][
                    "bytes_per_device_peak"] / 1e9,
                "fits_16gb": rec["production"]["per_device"][
                    "bytes_per_device_peak"] / 1e9 <= 16.0,
                "source": ("account" if src is rec.get("account")
                           else "production"),
            }
            rec["wall_s"] = round(time.time() - t0, 1)
            ledger[key] = rec
            _save(args.out, ledger)
            r = rec["roofline"]
            print(f"[ledger] {key}: c={r['compute_s']:.2e}s "
                  f"m={r['memory_s']:.2e}s x={r['collective_s']:.2e}s "
                  f"dom={r['dominant'][:-2]} useful={r['useful_ratio']:.2f} "
                  f"hbm={r['peak_hbm_gb']:.1f}GB ({rec['wall_s']}s)",
                  flush=True)

    n_ok = sum(1 for v in ledger.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in ledger.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in ledger.values() if v.get("status") == "error")
    print(f"[ledger] done: ok={n_ok} skipped={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


def _save(path, ledger):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(ledger, f, indent=1)


if __name__ == "__main__":
    sys.exit(main())
