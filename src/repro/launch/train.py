"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --reduced --steps 100 --batch 8 --seq 128 --cim emulate

Runs on whatever devices exist (single CPU here; the production mesh via
--mesh pod|multipod on a real fleet). Wires the fault-tolerant loop:
auto-resume from the newest checkpoint, async saves, straggler monitor.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--cim", default="off",
                    choices=["off", "emulate", "deploy"])
    ap.add_argument("--cim-bits", type=int, default=4)
    ap.add_argument("--cim-cell-bits", type=int, default=2)
    ap.add_argument("--cim-psum-bits", type=int, default=6)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a failure at this step (FT testing)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs.base import RunConfig
    from repro.configs.registry import get_config
    from repro.core.cim_linear import CIMConfig
    from repro.data.pipeline import make_lm_pipeline
    from repro.models.registry import get_model
    from repro.nn.module import init_params
    from repro.runtime.fault_tolerance import FaultTolerantLoop, TrainLoopState
    from repro.train.trainer import make_train_step

    cim = None
    if args.cim != "off":
        cim = CIMConfig(enabled=True, mode=args.cim,
                        weight_bits=args.cim_bits,
                        cell_bits=args.cim_cell_bits,
                        psum_bits=args.cim_psum_bits,
                        array_rows=128, array_cols=128)
    cfg = get_config(args.arch, reduced=args.reduced, cim=cim)
    run = RunConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(1, args.steps // 10),
                    accum_steps=args.accum, optimizer=args.optimizer,
                    checkpoint_dir=args.ckpt_dir,
                    checkpoint_every=args.ckpt_every, seed=args.seed)
    model = get_model(cfg)

    def make_batches():
        pipe = make_lm_pipeline(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch, seed=args.seed)
        for raw in pipe:
            batch = {"tokens": jnp.asarray(raw["tokens"])}
            if cfg.family in ("llava", "whisper"):
                fd = cfg.frontend_dim or cfg.d_model
                batch["frontend"] = jnp.zeros(
                    (args.batch, cfg.n_frontend_tokens, fd), jnp.float32)
            yield batch

    init_state_fn, train_step = make_train_step(model, cfg, run)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))

    def fresh():
        params = init_params(model.specs(cfg), jax.random.PRNGKey(args.seed))
        return TrainLoopState(params=params, opt_state=init_state_fn(params),
                              step=0)

    loop = FaultTolerantLoop(args.ckpt_dir,
                             checkpoint_every=args.ckpt_every)
    state = loop.resume_or_init(fresh)
    if state.step:
        print(f"[train] resumed from step {state.step}")

    t0 = time.time()
    tokens_per_step = args.batch * args.seq

    def on_metrics(step, m):
        dt = time.time() - t0
        print(f"[train] step {step:5d} loss {float(m['loss']):.4f} "
              f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} "
              f"({step * tokens_per_step / max(dt, 1e-9):.0f} tok/s)")

    state = loop.run(state, train_step, make_batches(),
                     total_steps=args.steps, crash_at_step=args.crash_at,
                     log_every=args.log_every, on_metrics=on_metrics)
    print(f"[train] done at step {state.step} "
          f"({time.time() - t0:.1f}s, straggler warns="
          f"{loop.straggler.n_warn})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
