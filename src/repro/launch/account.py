"""Exact roofline accounting from compiled HLO, correcting for loop bodies.

``cost_analysis()`` (and HLO text) count each loop body ONCE, regardless of
trip count — scanned layers, microbatch accumulation and chunked-attention
/ SSD scans would all be undercounted. This module derives exact totals
with only small compiles:

1. **Layer unrolling + two-point extrapolation.** Lower the cell with
   ``scan_layers=False`` at two small layer counts L1 < L2 (cheap HLO).
   Per-layer slope b = (M(L2) - M(L1)) / (L2 - L1); total(L) = M(L1) +
   b * (L - L1). Heterogeneous stacks (MoE dense+routed, whisper enc/dec)
   use one extra point per layer kind — an exact linear solve, since
   layer costs are exactly additive in HLO.

2. **Chunk-scan halving.** Inner scans (flash-attention KV chunks, SSD /
   mLSTM chunkwise) are loops whose body size is linear in the chunk
   length c. Lower at c and c/2: body(c) = 2 * (M(c) - M(c/2)); corrected
   M* = M + (trips - 1) * body(c), trips = ceil(T / c).

Microbatch accumulation is simply lowered with accum=1 (the accounting
cell), so no correction is needed. sLSTM's per-timestep recurrence
(~4*nh*hd^2 FLOPs/token, <2% of any xlstm cell) is added analytically.

The production cell (scanned, accumulated, full remat) remains the source
of memory_analysis — loop buffer reuse is exactly what it models well.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import SHAPES, MoEConfig, Shape
from repro.configs.registry import get_config


@dataclasses.dataclass
class Measurement:
    flops: float
    bytes_: float
    coll: float

    def __add__(self, o):
        return Measurement(self.flops + o.flops, self.bytes_ + o.bytes_,
                           self.coll + o.coll)

    def __sub__(self, o):
        return Measurement(self.flops - o.flops, self.bytes_ - o.bytes_,
                           self.coll - o.coll)

    def __mul__(self, k: float):
        return Measurement(self.flops * k, self.bytes_ * k, self.coll * k)

    __rmul__ = __mul__


def _measure(arch: str, shape_name: str, mesh, overrides: Dict,
             cim=None, accum: int = 1, run_overrides: Optional[Dict] = None
             ) -> Measurement:
    from .cells import build_cell
    from .dryrun import collective_bytes_from_hlo
    ov = dict(overrides)
    ov["scan_layers"] = False
    ro = dict(run_overrides or {})
    if accum > 1:
        ro["accum_unroll"] = True
    cell = build_cell(arch, shape_name, mesh, cim=cim, accum=accum,
                      overrides=ov, run_overrides=ro)
    compiled = cell.lower().compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    return Measurement(float(cost.get("flops", 0.0)),
                       float(cost.get("bytes accessed", 0.0)),
                       float(coll["total"]))


# ---------------------------------------------------------------------------
# per-family layer variants: (overrides, layer_vector) points + target vector
# ---------------------------------------------------------------------------

def _layer_plan(arch: str) -> Tuple[List[Tuple[Dict, Tuple[int, ...]]],
                                    Tuple[int, ...]]:
    cfg = get_config(arch)
    fam = cfg.family
    if fam == "whisper":
        pts = [({"enc_layers": 2, "n_layers": 2}, (2, 2)),
               ({"enc_layers": 4, "n_layers": 2}, (4, 2)),
               ({"enc_layers": 2, "n_layers": 4}, (2, 4))]
        return pts, (cfg.enc_layers, cfg.n_layers)
    if fam == "xlstm":
        e = cfg.ssm.slstm_every
        pts = [({"n_layers": e}, (e,)), ({"n_layers": 2 * e}, (2 * e,))]
        return pts, (cfg.n_layers,)
    if fam == "zamba2":
        e = cfg.attn_every
        pts = [({"n_layers": e}, (e,)), ({"n_layers": 2 * e}, (2 * e,))]
        return pts, (cfg.n_layers,)
    if cfg.moe is not None:
        moe = cfg.moe
        def m(ld, lm):
            return {"n_layers": ld + lm,
                    "moe": dataclasses.replace(moe, n_dense_layers=ld)}
        pts = [(m(1, 2), (1, 2)), (m(1, 4), (1, 4)), (m(2, 2), (2, 2))]
        return pts, (moe.n_dense_layers, cfg.n_layers - moe.n_dense_layers)
    pts = [({"n_layers": 2}, (2,)), ({"n_layers": 4}, (4,))]
    return pts, (cfg.n_layers,)


def _chunk_knobs(arch: str, shape: Shape) -> List[Tuple[str, int, int]]:
    """[(override_key, full_chunk, trips)] for inner scans in this cell."""
    cfg = get_config(arch)
    knobs = []
    t = shape.seq_len
    if shape.kind in ("train", "prefill"):
        c = cfg.attn_chunk
        has_attn = cfg.family in ("transformer", "llava", "whisper", "zamba2")
        if has_attn and c and t > c:
            knobs.append(("attn_chunk", c, int(np.ceil(t / c))))
        if cfg.family in ("xlstm", "zamba2") and cfg.ssm is not None:
            cs = cfg.ssm.chunk
            if t > cs:
                knobs.append(("ssm_chunk", cs, int(np.ceil(t / cs))))
    return knobs


def _apply_chunk(overrides: Dict, arch: str, key: str, value: int) -> Dict:
    ov = dict(overrides)
    if key == "attn_chunk":
        ov["attn_chunk"] = value
    else:
        cfg = get_config(arch)
        ssm = ov.get("ssm", cfg.ssm)
        ov["ssm"] = dataclasses.replace(ssm, chunk=value)
    return ov


def _slstm_flops(arch: str, shape: Shape) -> float:
    """Analytic recurrence FLOPs for xlstm's sLSTM blocks (scan over T is
    a loop the two-point method cannot see; contribution < 2%)."""
    cfg = get_config(arch)
    if cfg.family != "xlstm":
        return 0.0
    n_s = sum(1 for i in range(cfg.n_layers)
              if cfg.ssm.slstm_every and
              i % cfg.ssm.slstm_every == cfg.ssm.slstm_every - 1)
    nh = cfg.ssm.n_slstm_heads
    hd = cfg.d_model // nh
    per_tok = 4 * 2 * nh * hd * hd          # 4 gates x recurrent matmul
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 3 if shape.kind == "train" else 1   # fwd+bwd
    return float(n_s * per_tok * tokens * mult)


def account_cell(arch: str, shape_name: str, mesh, cim=None,
                 verbose: bool = True, overrides: Optional[Dict] = None,
                 run_overrides: Optional[Dict] = None,
                 accum: Optional[int] = None) -> Dict:
    """Exact per-device totals (flops, bytes, collective bytes).

    When the production run uses gradient accumulation A > 1, the extra
    per-microbatch cost (e.g. FSDP weight re-gathers) is measured by an
    unrolled accum=2 point and extrapolated: total(A) = M(1) + (A-1) *
    (M(2) - M(1)). Work that only depends on total tokens cancels in the
    delta, so only genuinely accum-proportional costs scale."""
    from .cells import make_run_config
    shape = SHAPES[shape_name]
    pts, target = _layer_plan(arch)
    knobs = _chunk_knobs(arch, shape)
    user_ov = dict(overrides or {})
    target_accum = (accum if accum is not None
                    else make_run_config(arch, shape,
                                         run_overrides=run_overrides
                                         ).accum_steps)

    corrected: List[Measurement] = []
    for overrides_pt, lv in pts:
        base_ov = dict(user_ov)
        base_ov.update(overrides_pt)
        for key, c, _tr in knobs:
            base_ov = _apply_chunk(base_ov, arch, key, c)
        m = _measure(arch, shape_name, mesh, base_ov, cim=cim,
                     run_overrides=run_overrides)
        m_corr = m
        for key, c, trips in knobs:
            if trips <= 1:
                continue
            half_ov = _apply_chunk(base_ov, arch, key, max(1, c // 2))
            m_half = _measure(arch, shape_name, mesh, half_ov, cim=cim,
                              run_overrides=run_overrides)
            body = 2.0 * (m - m_half)
            body = Measurement(max(body.flops, 0.0), max(body.bytes_, 0.0),
                               max(body.coll, 0.0))
            m_corr = m_corr + (trips - 1) * body
        if shape.kind == "train" and target_accum > 1:
            m2 = _measure(arch, shape_name, mesh, base_ov, cim=cim,
                          accum=2, run_overrides=run_overrides)
            delta = m2 - m
            delta = Measurement(max(delta.flops, 0.0),
                                max(delta.bytes_, 0.0),
                                max(delta.coll, 0.0))
            m_corr = m_corr + (target_accum - 1) * delta
        corrected.append(m_corr)

    # exact linear solve: M = a + sum_i b_i * L_i
    X = np.array([[1.0] + list(map(float, lv)) for _, lv in pts])
    out: Dict[str, float] = {}
    for field in ("flops", "bytes_", "coll"):
        y = np.array([getattr(m, field) for m in corrected])
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        total = coef[0] + sum(c * l for c, l in zip(coef[1:], target))
        out[field] = float(max(total, 0.0))
    out["flops"] += _slstm_flops(arch, shape) / mesh.devices.size
    if verbose:
        print(f"[account] {arch} x {shape_name}: per-dev flops "
              f"{out['flops']:.3e} bytes {out['bytes_']:.3e} "
              f"coll {out['coll']:.3e} ({len(pts)} pts x "
              f"{1 + len(knobs)} chunk variants)")
    return {"hlo_flops": out["flops"], "hlo_bytes": out["bytes_"],
            "collective_bytes": out["coll"]}
