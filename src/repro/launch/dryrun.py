import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST run before any other import: jax locks the device count on first
# init. The dry-run (and only the dry-run) builds 512 placeholder host
# devices so jax.make_mesh can assemble the production meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
single-pod (16,16) and multi-pod (2,16,16) production meshes, print
memory_analysis / cost_analysis, and extract the three roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json

Roofline terms (TPU v5e targets):
  compute    = HLO_FLOPs / (chips * 197e12 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s)
  collective = collective_bytes / (chips * 50e9 B/s per ICI link)

collective_bytes is parsed from the compiled HLO (operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-
permute); cost_analysis provides FLOPs and HBM bytes.
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

PEAK_FLOPS = 197e12            # bf16 / chip
HBM_BW = 819e9                 # B/s / chip
ICI_BW = 50e9                  # B/s / link


# ---------------------------------------------------------------------------
# HLO collective-byte accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by kind."""
    out = {k: 0 for k in _COLLECTIVES}
    out["n_ops"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # "x = f32[...] all-gather(...)" — op name after the result shape
        m = re.match(r"[%\w.\-]+\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if opname.startswith(kind):
                out[kind] += _shape_bytes(shape_str)
                out["n_ops"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# model-FLOPs estimates (6*N_active*D) for the usefulness ratio
# ---------------------------------------------------------------------------

def param_counts(cell) -> Dict[str, int]:
    import numpy as np
    from repro.models.registry import get_model
    from repro.nn.module import eval_shape_params
    model = get_model(cell.cfg)
    struct = eval_shape_params(model.specs(cell.cfg))
    leaves = {"/".join(map(str, p)): l for p, l in _walk(struct)}
    total = sum(int(np.prod(l.shape)) for l in leaves.values())
    # active params for MoE: routed experts contribute top_k/n_experts
    active = 0
    moe = cell.cfg.moe
    for path, l in leaves.items():
        n = int(np.prod(l.shape))
        is_expert = (moe is not None and "/moe/" in "/" + path + "/"
                     and path.rsplit("/", 1)[-1] in ("wg", "wu", "wd")
                     and len(l.shape) >= 3 and l.shape[-3] == moe.n_experts)
        if is_expert:
            active += n * moe.top_k // moe.n_experts
        else:
            active += n
    return {"total": total, "active": active}


def _walk(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], path + (k,))
    else:
        yield path, tree


def model_flops(cell) -> float:
    """6 * N_active * tokens (train) / 2 * N_active * tokens (inference)."""
    pc = param_counts(cell)
    n = pc["active"]
    sh = cell.shape
    if cell.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n * tokens
    tokens = sh.global_batch * 1
    return 2.0 * n * tokens


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             cim: Optional[str] = None, verbose: bool = True,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    from repro.configs.registry import cell_status
    from repro.core.cim_linear import CIMConfig
    from repro.core.granularity import Granularity
    from .cells import build_cell
    from .mesh import make_production_mesh

    ok, why = cell_status(arch, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "cim": cim or "off"}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        if verbose:
            print(f"[dryrun] {arch} x {shape_name}: SKIP ({why})")
        return rec

    cim_cfg = None
    if cim and cim != "off":
        cim_cfg = CIMConfig(
            enabled=True, mode=cim, weight_bits=4, cell_bits=2, act_bits=8,
            psum_bits=6, array_rows=256, array_cols=256,
            weight_granularity=Granularity.COLUMN,
            psum_granularity=Granularity.COLUMN, use_kernel=False)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, cim=cim_cfg,
                      overrides=overrides)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    flops = float(cost.get("flops", 0.0))
    # cost_analysis flops on the host backend are per-program (global HLO
    # was partitioned): treat as per-device and scale to global.
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    mf = model_flops(cell)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": (coll["total"]) / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    rec.update({
        "status": "ok",
        "chips": chips,
        "kind": cell.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "hlo_flops": flops,
            "hlo_bytes": bytes_accessed,
            "collective_bytes": coll["total"],
            "collective_ops": coll["n_ops"],
            "bytes_per_device_argument": int(mem.argument_size_in_bytes),
            "bytes_per_device_output": int(mem.output_size_in_bytes),
            "bytes_per_device_temp": int(mem.temp_size_in_bytes),
            "bytes_per_device_alias": int(mem.alias_size_in_bytes),
            # donated args alias their outputs; peak = args + temp + net out
            "bytes_per_device_peak": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + max(0, mem.output_size_in_bytes - mem.alias_size_in_bytes)),
        },
        "collectives": {k: coll[k] for k in _COLLECTIVES},
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_global": mf,
            "useful_ratio": (mf / chips) / max(flops, 1.0),
        },
    })
    if verbose:
        pd = rec["per_device"]
        print(f"[dryrun] {arch} x {shape_name} ({'2x16x16' if multi_pod else '16x16'}"
              f", cim={cim or 'off'}): OK  kind={cell.kind}")
        print(f"  lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"per-dev FLOPs {pd['hlo_flops']:.3e} bytes {pd['hlo_bytes']:.3e} "
              f"coll {pd['collective_bytes']:.3e} ({pd['collective_ops']} ops)")
        print(f"  HBM/device: args {pd['bytes_per_device_argument']/1e9:.2f}GB "
              f"out {pd['bytes_per_device_output']/1e9:.2f}GB "
              f"temp {pd['bytes_per_device_temp']/1e9:.2f}GB "
              f"peak {pd['bytes_per_device_peak']/1e9:.2f}GB")
        r = rec["roofline"]
        print(f"  roofline: compute {r['compute_s']:.3e}s memory "
              f"{r['memory_s']:.3e}s collective {r['collective_s']:.3e}s "
              f"-> dominant={r['dominant']} useful={r['useful_ratio']:.2f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cim", default="off", choices=["off", "emulate", "deploy"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        cim=args.cim))
            except Exception as e:
                failures += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "multi_pod": mp, "status": "error",
                                "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} records to {args.out}")
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"[dryrun] ok={n_ok} skipped={n_skip} failed={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
