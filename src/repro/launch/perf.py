import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Each experiment = named variant of a cell (cfg/run/cim overrides). The
driver measures the three roofline terms via the loop-corrected
accounting, plus per-kind collective bytes and the production memory fit,
and appends JSON records:

  PYTHONPATH=src python -m repro.launch.perf --cell moe_train --out results/perf.json
"""
import argparse
import json
import sys
import time
import traceback

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def _cim(mode="deploy", wb=4, cb=2, pb=6, pack="int8", use_kernel=False):
    from repro.core.cim_linear import CIMConfig
    from repro.core.granularity import Granularity
    return CIMConfig(enabled=True, mode=mode, weight_bits=wb, cell_bits=cb,
                     act_bits=8, psum_bits=pb, array_rows=256,
                     array_cols=256,
                     weight_granularity=Granularity.COLUMN,
                     psum_granularity=Granularity.COLUMN,
                     use_kernel=use_kernel, pack_dtype=pack)


# experiment registry: cell -> [(variant_name, kwargs for build_cell)]
EXPERIMENTS = {
    # most collective-bound cell: MoE training. The auto-SPMD dispatch
    # replicates the (E, cap, d) buffers across 'model' (involuntary
    # resharding) -> the shard_map EP dispatch exploits activation
    # replication at the MoE block: zero all_to_all, one psum per layer.
    "moe_train": {
        "arch": "moonshot-v1-16b-a3b", "shape": "train_4k",
        "variants": [
            ("baseline_autospmd", {}),
            ("ep_shardmap", {"overrides": {"moe_impl": "auto"}}),
            ("ep_zero1", {"overrides": {"moe_impl": "auto"},
              "run_overrides": {"fsdp": False, "zero1": True}}),
            ("ep_zero1_accum4", {"overrides": {"moe_impl": "auto"},
                                 "run_overrides": {"fsdp": False,
                                                   "zero1": True},
                                 "accum": 4}),
        ],
    },
    # the paper-representative cell: quantized-weight decode. Baseline's
    # dominant term is collective (per-layer KV-cache gathers caused by
    # the head-sharded-new-KV vs time-sharded-cache mismatch); flash
    # decode fixes that, then the paper's column-quantized int weights
    # attack the memory term.
    "decode_quant": {
        "arch": "llama3-8b", "shape": "decode_32k",
        "variants": [
            ("baseline_bf16", {}),
            ("flash_decode", {"overrides": {"flash_decode": True}}),
            ("flash_cim_int8", {"overrides": {"flash_decode": True},
                                "cim": _cim(pack="int8")}),
            ("flash_cim_int4", {"overrides": {"flash_decode": True},
                                "cim": _cim(pack="int4")}),
            ("flash_kv8", {"overrides": {"flash_decode": True,
                                         "kv_cache_dtype": "int8"}}),
            ("flash_kv8_cim_int4", {"overrides": {"flash_decode": True,
                                                  "kv_cache_dtype": "int8"},
                                    "cim": _cim(pack="int4")}),
        ],
    },
    # third cell: 32k prefill (worst useful-ratio among the fitting
    # dense cells): flash-chunk size trades recompute vs score traffic
    "prefill": {
        "arch": "llama3-8b", "shape": "prefill_32k",
        "variants": [
            ("baseline_chunk2048", {}),
            ("chunk4096", {"overrides": {"attn_chunk": 4096}}),
            ("chunk8192", {"overrides": {"attn_chunk": 8192}}),
            ("chunk4096_cim_int4", {"overrides": {"attn_chunk": 4096},
                                    "cim": _cim(pack="int4")}),
        ],
    },
}


def measure(arch, shape, *, label, out_path, ledger, **kw):
    from repro.launch.account import account_cell
    from repro.launch.cells import build_cell
    from repro.launch.dryrun import (collective_bytes_from_hlo, model_flops,
                                     run_cell)
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    t0 = time.time()
    rec = {"label": label, "arch": arch, "shape": shape}
    try:
        # production compile: memory fit + per-kind collectives
        cell = build_cell(arch, shape, mesh, **kw)
        compiled = cell.lower().compile()
        mem = compiled.memory_analysis()
        coll = collective_bytes_from_hlo(compiled.as_text())
        rec["peak_hbm_gb"] = (mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + max(0, mem.output_size_in_bytes
                                    - mem.alias_size_in_bytes)) / 1e9
        rec["collectives_prod"] = {k: v for k, v in coll.items()
                                   if k != "n_ops"}
        # loop-corrected accounting with the same variant knobs
        acct = account_cell(arch, shape, mesh, cim=kw.get("cim"),
                            verbose=False,
                            overrides=kw.get("overrides"),
                            run_overrides=kw.get("run_overrides"),
                            accum=kw.get("accum"))
        rec.update(acct)
        rec["roofline"] = {
            "compute_s": acct["hlo_flops"] / PEAK_FLOPS,
            "memory_s": acct["hlo_bytes"] / HBM_BW,
            "collective_s": acct["collective_bytes"] / ICI_BW,
        }
        rec["roofline"]["dominant"] = max(rec["roofline"],
                                          key=rec["roofline"].get)
        mf = model_flops(cell)
        rec["useful_ratio"] = (mf / 256) / max(acct["hlo_flops"], 1.0)
        rec["status"] = "ok"
    except Exception as e:
        traceback.print_exc()
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
    rec["wall_s"] = round(time.time() - t0, 1)
    ledger.append(rec)
    with open(out_path, "w") as f:
        json.dump(ledger, f, indent=1)
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(f"[perf] {label}: c={r['compute_s']:.3e} m={r['memory_s']:.3e}"
              f" x={r['collective_s']:.3e} dom={r['dominant']}"
              f" hbm={rec['peak_hbm_gb']:.1f}GB useful="
              f"{rec['useful_ratio']:.2f} ({rec['wall_s']}s)", flush=True)
    else:
        print(f"[perf] {label}: ERROR {rec['error']}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(EXPERIMENTS))
    ap.add_argument("--out", default=None)
    ap.add_argument("--variant", default=None)
    args = ap.parse_args(argv)
    exp = EXPERIMENTS[args.cell]
    out = args.out or f"results/perf_{args.cell}.json"
    ledger = []
    if os.path.exists(out):
        with open(out) as f:
            ledger = json.load(f)
    done = {r["label"] for r in ledger if r.get("status") == "ok"}
    for label, kw in exp["variants"]:
        if args.variant and label != args.variant:
            continue
        if label in done:
            continue
        measure(exp["arch"], exp["shape"], label=label, out_path=out,
                ledger=ledger, **kw)
    return 0


if __name__ == "__main__":
    sys.exit(main())
