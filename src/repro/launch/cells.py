"""Cell builder: (arch x shape x mesh) -> a lowerable step.

For each assigned cell this module produces:
  * the step function (train_step for train shapes; cached prefill for
    prefill shapes; single-token serve_step for decode shapes),
  * ShapeDtypeStruct stand-ins for every argument (params via eval_shape —
    zero allocation),
  * in/out shardings resolved from the logical annotations.

Per-arch RUN_HINTS encode how the cell fits the production mesh: FSDP for
>=2B params, microbatch accumulation for the 1M-token train shape, bf16
params+optimizer state for the 671B model (2+2+2 bytes/param = 4TB on 512
chips), adafactor fallbacks, remat always on for train.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, RunConfig, Shape
from repro.configs.registry import get_config
from repro.models.registry import ModelFns, get_model
from repro.nn.module import (eval_shape_params, logical_to_mesh,
                             resolve_pspec, set_activation_rules)
from repro.optim.optimizer import make_optimizer
from repro.train.trainer import lm_loss_fn, make_train_step
from .mesh import batch_axes, sharding_rules

# how each arch runs at scale (param count driven)
RUN_HINTS: Dict[str, Dict[str, Any]] = {
    "moonshot-v1-16b-a3b": dict(fsdp=True, accum_steps=8),
    "deepseek-v3-671b": dict(fsdp=True, accum_steps=32,
                             param_dtype="bfloat16",
                             optimizer="adafactor",
                             opt_state_dtype="bfloat16"),
    "qwen3-0.6b": dict(fsdp=False, accum_steps=4),
    "llama3-8b": dict(fsdp=True, accum_steps=8),
    "granite-8b": dict(fsdp=True, accum_steps=8),
    "olmo-1b": dict(fsdp=False, accum_steps=4),
    "xlstm-1.3b": dict(fsdp=False, accum_steps=8),
    "llava-next-mistral-7b": dict(fsdp=True, accum_steps=8),
    "whisper-small": dict(fsdp=False, accum_steps=2),
    "zamba2-2.7b": dict(fsdp=True, accum_steps=8),
}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: Shape
    cfg: ModelConfig
    mesh: Mesh
    step_fn: Callable            # positional args matching arg_structs
    arg_structs: Tuple           # ShapeDtypeStructs (no allocation)
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...]
    kind: str                    # train | prefill | decode

    rules: Any = None

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        set_activation_rules(self.rules, mesh=self.mesh)
        try:
            with self.mesh:
                return jitted.lower(*self.arg_structs)
        finally:
            set_activation_rules(None)


# ---------------------------------------------------------------------------
# input stand-ins
# ---------------------------------------------------------------------------

def batch_structs(cfg: ModelConfig, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training batch stand-ins (tokens + optional frontend stub)."""
    b, t = shape.global_batch, shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "llava":
        text = t - cfg.n_frontend_tokens
        out["tokens"] = jax.ShapeDtypeStruct((b, text + 1), jnp.int32)
        out["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.frontend_dim or cfg.d_model),
            jnp.float32)
    elif cfg.family == "whisper":
        out["tokens"] = jax.ShapeDtypeStruct((b, t + 1), jnp.int32)
        out["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, t + 1), jnp.int32)
    return out


def batch_shardings(cfg: ModelConfig, mesh: Mesh) -> Dict[str, NamedSharding]:
    b = batch_axes(mesh)
    sh = {"tokens": NamedSharding(mesh, P(b))}
    if cfg.family in ("llava", "whisper"):
        sh["frontend"] = NamedSharding(mesh, P(b))
    return sh


def _dim_axis_ok(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return False
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def cache_shardings(cache_struct, cfg: ModelConfig, mesh: Mesh):
    """Decode-cache sharding: batch dim over (pod,data) when divisible;
    the KV *time* dim over 'model' (sequence-parallel decode attention —
    how a 550GB 32k x 128 KV cache fits 16GB chips)."""
    b = batch_axes(mesh)

    def leaf_spec(path_leaf, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        # heuristics by rank/name: all caches are stacked (L, B, ...) except
        # whisper enc_out (B, F, D) and top-level len (L, B)
        name = path_leaf[-1] if path_leaf else ""
        if name == "enc_out":
            if _dim_axis_ok(shape[0], mesh, b):
                spec[0] = b
            return P(*spec)
        if len(shape) >= 2:
            if _dim_axis_ok(shape[1], mesh, b):
                spec[1] = b
        if name in ("k", "v", "ckv", "krope", "k_scale", "v_scale") \
                and len(shape) >= 3:
            if _dim_axis_ok(shape[2], mesh, "model"):
                spec[2] = "model"
        if name in ("ssd",) and len(shape) >= 3:
            if _dim_axis_ok(shape[2], mesh, "model"):
                spec[2] = "model"
        return P(*spec)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        return NamedSharding(mesh, leaf_spec(path, tree))

    return walk(cache_struct)


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def make_run_config(arch: str, shape: Shape, *, accum: Optional[int] = None,
                    run_overrides: Optional[Dict[str, Any]] = None
                    ) -> RunConfig:
    hints = dict(RUN_HINTS.get(arch, {}))
    if run_overrides:
        hints.update(run_overrides)
    return RunConfig(
        fsdp=hints.get("fsdp", False),
        accum_steps=(accum if accum is not None
                     else (hints.get("accum_steps", 1)
                           if shape.kind == "train" else 1)),
        accum_unroll=hints.get("accum_unroll", False),
        optimizer=hints.get("optimizer", "adamw"),
        opt_state_dtype=hints.get("opt_state_dtype", "float32"),
    )


def apply_hints(cfg: ModelConfig, arch: str) -> ModelConfig:
    hints = RUN_HINTS.get(arch, {})
    kw = {}
    if "param_dtype" in hints:
        kw["param_dtype"] = hints["param_dtype"]
    return cfg.replace(**kw) if kw else cfg


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               reduced: bool = False, cim=None,
               accum: Optional[int] = None,
               overrides: Optional[Dict[str, Any]] = None,
               run_overrides: Optional[Dict[str, Any]] = None) -> Cell:
    shape = SHAPES[shape_name]
    cfg = get_config(arch, reduced=reduced, cim=cim)
    cfg = apply_hints(cfg, arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    run = make_run_config(arch, shape, accum=accum,
                          run_overrides=run_overrides)
    zero1 = bool((run_overrides or {}).get(
        "zero1", RUN_HINTS.get(arch, {}).get("zero1", False)))
    model = get_model(cfg)
    rules = sharding_rules(mesh, fsdp=run.fsdp)

    specs = model.specs(cfg)
    params_struct = eval_shape_params(specs)
    pspecs = logical_to_mesh(specs, rules)
    params_sh = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
    # drop mesh axes on dims they don't divide (odd vocabs, 4d/3 FFNs, ...)
    params_sh = jax.tree.map(
        lambda sh, st: _truncate_sharding(sh, st, mesh), params_sh,
        params_struct)

    if shape.kind == "train":
        init_state, train_step = make_train_step(model, cfg, run)
        opt_struct = jax.eval_shape(init_state, params_struct)
        opt_sh = _opt_shardings(opt_struct, params_sh, mesh)
        if zero1:
            # ZeRO-1: optimizer states sharded over the batch axes even
            # though params are replicated there — one param all-gather
            # per step instead of FSDP's per-microbatch weight gathers
            opt_sh = _zero1_shardings(opt_sh, opt_struct, mesh)
        bstructs = batch_structs(cfg, shape)
        bsh = batch_shardings(cfg, mesh)
        metrics_sh = NamedSharding(mesh, P())
        return Cell(
            arch=arch, shape=shape, cfg=cfg, mesh=mesh, kind="train",
            rules=rules,
            step_fn=train_step,
            arg_structs=(params_struct, opt_struct, bstructs),
            in_shardings=(params_sh, opt_sh, bsh),
            out_shardings=(params_sh, opt_sh,
                           jax.tree.map(lambda _: metrics_sh,
                                        {"loss": 0, "grad_norm": 0, "lr": 0,
                                         "step": 0})),
            donate=(0, 1),
        )

    # inference shapes
    b = shape.global_batch
    if shape.kind == "prefill":
        tok_len = shape.seq_len
        cache_len = shape.seq_len
    else:                                    # decode: one token, full cache
        tok_len = 1
        cache_len = shape.seq_len
        # single-query attention needs no KV chunking; full attention over
        # the time-sharded cache lowers to a clean partial-softmax + psum
        # (the chunk-scan reshape would break the model-axis time sharding)
        cfg = cfg.replace(attn_chunk=0)
    cache_struct = jax.eval_shape(
        partial(model.init_cache, cfg, b, cache_len))
    cache_sh = cache_shardings(cache_struct, cfg, mesh)
    tok_struct = jax.ShapeDtypeStruct((b, tok_len), jnp.int32)
    bspec = batch_axes(mesh) if _dim_axis_ok(b, mesh, batch_axes(mesh)) \
        else None
    tok_sh = NamedSharding(mesh, P(bspec))

    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens, cfg)
        return logits, new_cache

    vspec = "model" if _dim_axis_ok(cfg.vocab, mesh, "model") else None
    logits_sh = NamedSharding(mesh, P(bspec, None, vspec))
    return Cell(
        arch=arch, shape=shape, cfg=cfg, mesh=mesh, kind=shape.kind,
        rules=rules,
        step_fn=serve_step,
        arg_structs=(params_struct, cache_struct, tok_struct),
        in_shardings=(params_sh, cache_sh, tok_sh),
        out_shardings=(logits_sh, cache_sh),
        donate=(1,),
    )


def _opt_shardings(opt_struct, params_sh, mesh):
    """Optimizer state mirrors the parameter shardings (m/v/mom follow
    their parameter; adafactor vr/vc follow with the reduced dim dropped;
    scalars replicated)."""
    flat_p = dict(_flatten_tree(params_sh))

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        sub = path[1:]                     # drop the state kind (m/v/mom/..)
        if not sub:                        # e.g. "step"
            return NamedSharding(mesh, P())
        key = "/".join(sub)
        if key in flat_p:
            return _truncate_sharding(flat_p[key], tree, mesh)
        name = sub[-1]
        pkey = "/".join(sub[:-1])
        if name in ("vr", "vc", "v") and pkey in flat_p:
            psh = flat_p[pkey]
            spec = list(psh.spec)
            spec += [None] * (len(tree.shape) + 2 - len(spec))
            if name == "vr":               # param reduced over last dim
                spec = spec[:len(tree.shape)]
            elif name == "vc":             # param reduced over dim -2
                spec = spec[:len(tree.shape) - 1] + [spec[len(tree.shape)]]
            else:
                spec = spec[:len(tree.shape)]
            return _truncate_sharding(NamedSharding(mesh, P(*spec)), tree, mesh)
        return NamedSharding(mesh, P())

    return walk(opt_struct)


def _truncate_sharding(psh: NamedSharding, leaf, mesh) -> NamedSharding:
    """Fit a parameter's PartitionSpec onto a (possibly lower-rank or
    reshaped) optimizer-state leaf; drop axes that no longer divide."""
    spec = list(psh.spec) + [None] * 8
    nd = len(leaf.shape)
    out = []
    for i in range(nd):
        ax = spec[i] if i < len(psh.spec) else None
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(ax if leaf.shape[i] % n == 0 and leaf.shape[i] >= n else None)
    return NamedSharding(mesh, P(*out))


def _zero1_shardings(opt_sh, opt_struct, mesh):
    """Add batch-axis sharding to optimizer-state leaves on the first
    divisible, currently-unsharded dim (ZeRO-1)."""
    b = batch_axes(mesh)
    nb = 1
    for a in b:
        nb *= mesh.shape[a]

    def walk(sh, st):
        if isinstance(sh, dict):
            return {k2: walk(sh[k2], st[k2]) for k2 in sh}
        if not st.shape:                      # scalars (step) stay replicated
            return sh
        spec = list(sh.spec) + [None] * (len(st.shape) - len(sh.spec))
        used = {a for s in spec if s is not None
                for a in ((s,) if isinstance(s, str) else s)}
        if any(a in used for a in b):
            return sh                          # already sharded over batch
        for i, dim in enumerate(st.shape):
            if spec[i] is None and dim % nb == 0 and dim >= nb:
                spec[i] = b if len(b) > 1 else b[0]
                return NamedSharding(mesh, P(*spec))
        return sh

    return walk(opt_sh, opt_struct)


def _flatten_tree(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten_tree(v, path + (k,))
    else:
        yield "/".join(path), tree
