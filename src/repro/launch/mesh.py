"""Production meshes and logical->mesh sharding rules.

Meshes are built by FUNCTIONS so importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — "pod"
composes with "data" for batch/FSDP sharding; "model" stays intra-pod
(TP/EP collectives ride the fast ICI, DP gradient reduction crosses DCN).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """All local devices on one 'data' axis (tests / CPU runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def sharding_rules(mesh: Mesh, *, fsdp: bool = False) -> Dict[str, object]:
    """Logical-axis rules consumed by nn.module.resolve_pspec.

    TP over 'model' (heads/mlp/vocab/experts); FSDP additionally shards
    the embed (d_model) axis of weights over the batch axes — XLA SPMD
    inserts the all-gathers (weights) / reduce-scatters (grads)."""
    b = batch_axes(mesh)
    rules: Dict[str, object] = {
        "batch": b,
        "vocab": "model" if "model" in mesh.axis_names else None,
        "heads": "model" if "model" in mesh.axis_names else None,
        "mlp": "model" if "model" in mesh.axis_names else None,
        "experts": "model" if "model" in mesh.axis_names else None,
        "embed": b if fsdp else None,
    }
    return rules
