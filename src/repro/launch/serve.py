"""Serving driver: batched generation with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 [--cim deploy]

Column-parallel serving (DESIGN.md §10): ``--mesh N`` shards every packed
layer's digit planes over an N-device ``("model",)`` mesh — one kernel
shard per device, bit-exact with ``--mesh 1``. On a CPU host, emulate the
devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --reduced --cim deploy --mesh 4

``--artifact PATH`` serves a saved ``DeployArtifact`` instead of packing
fresh random-init weights; with ``--mesh`` the planes are placed
shard-by-shard as they come off disk.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cim", default="off",
                    choices=["off", "emulate", "deploy"])
    ap.add_argument("--mesh", type=int, default=1,
                    help="devices along the 'model' axis: column-shard "
                         "packed digit planes (deploy/artifact serving "
                         "only; DESIGN.md §10)")
    ap.add_argument("--artifact", default=None,
                    help="path to a packed model DeployArtifact to serve "
                         "(implies the artifact's pinned deploy backend)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.core.cim_linear import CIMConfig
    from repro.models.registry import get_model
    from repro.nn.module import init_params
    from repro.serve.engine import ServingEngine, engine_from_artifact

    mesh = None
    if args.mesh > 1:
        if len(jax.devices()) < args.mesh:
            raise SystemExit(
                f"--mesh {args.mesh} needs {args.mesh} devices, found "
                f"{len(jax.devices())}. On a CPU host set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.mesh}")
        mesh = jax.make_mesh((args.mesh,), ("model",))
        if args.artifact is None and args.cim != "deploy":
            raise SystemExit("--mesh shards packed digit planes; use it "
                             "with --cim deploy or --artifact")

    cim = None
    if args.cim != "off":
        # QAT-shaped config; deploy serving packs these params below
        cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4,
                        cell_bits=2, act_bits=8, psum_bits=6,
                        array_rows=128, array_cols=128, use_kernel=False)
    cfg = get_config(args.arch, reduced=args.reduced, cim=cim)

    if args.artifact is not None:
        engine = engine_from_artifact(
            args.artifact, cfg, mesh=mesh, batch_size=args.batch,
            max_len=args.max_len, temperature=args.temperature,
            seed=args.seed)
    elif args.cim == "deploy":
        # pack random-init emulate params into an in-memory artifact and
        # serve it — the same packed bytes + engine path a saved artifact
        # takes, so --mesh N is exercised end to end
        from repro.api import model_artifact
        model = get_model(cfg)
        params = init_params(model.specs(cfg), jax.random.PRNGKey(args.seed))
        artifact = model_artifact(params, cim, meta={"arch": args.arch})
        engine = engine_from_artifact(
            artifact, cfg, mesh=mesh, batch_size=args.batch,
            max_len=args.max_len, temperature=args.temperature,
            seed=args.seed)
    else:
        model = get_model(cfg)
        params = init_params(model.specs(cfg), jax.random.PRNGKey(args.seed))
        engine = ServingEngine(model, cfg, params, batch_size=args.batch,
                               max_len=args.max_len,
                               temperature=args.temperature, seed=args.seed)
    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab, size=(args.batch, args.prompt_len)
                          ).astype(np.int32)
    t0 = time.time()
    out = engine.generate_batch(prompts, args.new_tokens)
    dt = time.time() - t0
    n_new = out.shape[0] * out.shape[1]
    devs = args.mesh if mesh is not None else 1
    print(f"[serve] arch={args.arch} mesh={devs} generated {out.shape} "
          f"tokens in {dt:.2f}s ({n_new / dt:.1f} tok/s)")
    print(f"[serve] sample continuation: {out[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
