"""Serving driver: batched generation with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 [--cim deploy]

Column-parallel serving (DESIGN.md §10): ``--mesh N`` shards every packed
layer's digit planes over an N-device ``("model",)`` mesh — one kernel
shard per device, bit-exact with ``--mesh 1``. On a CPU host, emulate the
devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --reduced --cim deploy --mesh 4

``--artifact PATH`` serves a saved ``DeployArtifact`` instead of packing
fresh random-init weights; with ``--mesh`` the planes are placed
shard-by-shard as they come off disk.

Self-healing serving (DESIGN.md §11): ``--drift-col-rate`` /
``--drift-cell-rate`` / ``--drift-read-sigma`` serve a drifting chip
(one keyed realization per decode step, clocked from ``--drift-t0``),
``--health`` arms the ``DriftMonitor``, and ``--auto-recal`` closes the
loop — past the hard threshold the engine re-fits the per-column scales
in place instead of degrading to the digital fallback.

Telemetry (DESIGN.md §12): ``--metrics-out PATH`` dumps the engine's
folded ``metrics()`` view (health + throughput + registry snapshot, and
ADC saturation when ``--adc-sample`` arms the collector) as JSON after
generation; ``--report-every N`` prints a one-line operator report to
stderr every N decode steps.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cim", default="off",
                    choices=["off", "emulate", "deploy"])
    ap.add_argument("--mesh", type=int, default=1,
                    help="devices along the 'model' axis: column-shard "
                         "packed digit planes (deploy/artifact serving "
                         "only; DESIGN.md §10)")
    ap.add_argument("--artifact", default=None,
                    help="path to a packed model DeployArtifact to serve "
                         "(implies the artifact's pinned deploy backend)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--drift-col-rate", type=float, default=0.0,
                    help="per-request column-gain drift rate "
                         "(core.variation.DriftSchedule.col_rate)")
    ap.add_argument("--drift-cell-rate", type=float, default=0.0,
                    help="per-request per-cell drift rate")
    ap.add_argument("--drift-read-sigma", type=float, default=0.0,
                    help="static read-noise sigma (re-drawn every step)")
    ap.add_argument("--drift-t0", type=int, default=0,
                    help="initial request count on the drift clock")
    ap.add_argument("--health", action="store_true",
                    help="arm the DriftMonitor and print the engine "
                         "health() snapshot after generation")
    ap.add_argument("--auto-recal", action="store_true",
                    help="recalibrate column scales automatically on "
                         "hard drift instead of serving the fallback")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write engine.metrics() (health + throughput + "
                         "metric snapshot) as JSON after generation")
    ap.add_argument("--report-every", type=int, default=0, metavar="N",
                    help="print a one-line metrics report to stderr every "
                         "N decode steps (0 = off)")
    ap.add_argument("--adc-sample", type=int, default=0, metavar="N",
                    help="arm the per-column ADC saturation collector, "
                         "folding every Nth kernel invocation (0 = off; "
                         "DESIGN.md §12)")
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.core.cim_linear import CIMConfig
    from repro.core.variation import DriftSchedule
    from repro.models.registry import get_model
    from repro.nn.module import init_params
    from repro.serve.engine import ServingEngine, engine_from_artifact
    from repro.serve.health import DriftMonitor

    drift_kw = {}
    drifting = (args.drift_col_rate or args.drift_cell_rate
                or args.drift_read_sigma)
    if drifting:
        drift_kw["drift_key"] = jax.random.fold_in(
            jax.random.PRNGKey(args.seed), 0xD81F)
        drift_kw["drift_schedule"] = DriftSchedule(
            read_sigma=args.drift_read_sigma,
            cell_rate=args.drift_cell_rate,
            col_rate=args.drift_col_rate)
    if args.health or args.auto_recal:
        drift_kw["health"] = DriftMonitor()
        drift_kw["auto_recalibrate"] = args.auto_recal
    if args.report_every:
        drift_kw["report_every"] = args.report_every
    if args.adc_sample:
        # arm BEFORE the engine builds: instrumentation is a trace-time
        # decision (repro.obs.adc)
        from repro.obs import adc
        adc.enable(every_n=args.adc_sample)

    mesh = None
    if args.mesh > 1:
        if len(jax.devices()) < args.mesh:
            raise SystemExit(
                f"--mesh {args.mesh} needs {args.mesh} devices, found "
                f"{len(jax.devices())}. On a CPU host set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.mesh}")
        mesh = jax.make_mesh((args.mesh,), ("model",))
        if args.artifact is None and args.cim != "deploy":
            raise SystemExit("--mesh shards packed digit planes; use it "
                             "with --cim deploy or --artifact")

    cim = None
    if args.cim != "off":
        # QAT-shaped config; deploy serving packs these params below
        cim = CIMConfig(enabled=True, mode="emulate", weight_bits=4,
                        cell_bits=2, act_bits=8, psum_bits=6,
                        array_rows=128, array_cols=128, use_kernel=False)
    cfg = get_config(args.arch, reduced=args.reduced, cim=cim)

    if args.artifact is not None:
        engine = engine_from_artifact(
            args.artifact, cfg, mesh=mesh, batch_size=args.batch,
            max_len=args.max_len, temperature=args.temperature,
            seed=args.seed, **drift_kw)
    elif args.cim == "deploy":
        # pack random-init emulate params into an in-memory artifact and
        # serve it — the same packed bytes + engine path a saved artifact
        # takes, so --mesh N is exercised end to end
        from repro.api import model_artifact
        model = get_model(cfg)
        params = init_params(model.specs(cfg), jax.random.PRNGKey(args.seed))
        artifact = model_artifact(params, cim, meta={"arch": args.arch})
        engine = engine_from_artifact(
            artifact, cfg, mesh=mesh, batch_size=args.batch,
            max_len=args.max_len, temperature=args.temperature,
            seed=args.seed, **drift_kw)
    else:
        if drifting:
            raise SystemExit("drift flags act on packed digit planes; use "
                             "them with --cim deploy or --artifact")
        model = get_model(cfg)
        params = init_params(model.specs(cfg), jax.random.PRNGKey(args.seed))
        engine = ServingEngine(model, cfg, params, batch_size=args.batch,
                               max_len=args.max_len,
                               temperature=args.temperature, seed=args.seed,
                               **drift_kw)
    engine.t = args.drift_t0
    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab, size=(args.batch, args.prompt_len)
                          ).astype(np.int32)
    t0 = time.time()
    out = engine.generate_batch(prompts, args.new_tokens)
    dt = time.time() - t0
    n_new = out.shape[0] * out.shape[1]
    devs = args.mesh if mesh is not None else 1
    print(f"[serve] arch={args.arch} mesh={devs} generated {out.shape} "
          f"tokens in {dt:.2f}s ({n_new / dt:.1f} tok/s)")
    print(f"[serve] sample continuation: {out[0][:16].tolist()}")
    h = engine.health()
    print(f"[serve] admission: submitted={h['submitted']} "
          f"retired={h['retired']} queue_depth={h['queue_depth']} "
          f"active_slots={h['active_slots']}/{h['slots']}")
    if args.health or args.auto_recal:
        print(f"[serve] health: {h}")
    if args.metrics_out:
        with open(args.metrics_out, "w", encoding="utf-8") as f:
            json.dump(engine.metrics(), f, indent=2, default=str)
        print(f"[serve] metrics -> {args.metrics_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
