"""Serving driver: batched generation with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 [--cim deploy]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cim", default="off",
                    choices=["off", "emulate", "deploy"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.core.cim_linear import CIMConfig
    from repro.models.registry import get_model
    from repro.nn.module import init_params
    from repro.serve.engine import ServingEngine

    cim = None
    if args.cim != "off":
        cim = CIMConfig(enabled=True, mode=args.cim, weight_bits=4,
                        cell_bits=2, act_bits=8, psum_bits=6,
                        array_rows=128, array_cols=128, use_kernel=False)
    cfg = get_config(args.arch, reduced=args.reduced, cim=cim)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.PRNGKey(args.seed))

    engine = ServingEngine(model, cfg, params, batch_size=args.batch,
                           max_len=args.max_len,
                           temperature=args.temperature, seed=args.seed)
    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab, size=(args.batch, args.prompt_len)
                          ).astype(np.int32)
    t0 = time.time()
    out = engine.generate_batch(prompts, args.new_tokens)
    dt = time.time() - t0
    n_new = out.shape[0] * out.shape[1]
    print(f"[serve] arch={args.arch} generated {out.shape} tokens in "
          f"{dt:.2f}s ({n_new / dt:.1f} tok/s)")
    print(f"[serve] sample continuation: {out[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
