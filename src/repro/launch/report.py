"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from ledger JSON.

  PYTHONPATH=src python -m repro.launch.report results/ledger.json
"""
from __future__ import annotations

import json
import sys


def fmt_b(x):
    if x >= 1e12:
        return f"{x/1e12:.2f}T"
    if x >= 1e9:
        return f"{x/1e9:.2f}G"
    if x >= 1e6:
        return f"{x/1e6:.2f}M"
    return f"{x:.0f}"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def roofline_table(ledger) -> str:
    rows = ["| arch | shape | kind | compute | memory | collective | "
            "dominant | useful | HBM/dev | fits 16GB |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(ledger):
        rec = ledger[key]
        arch, shape = key.split("|")
        if rec.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | — | "
                        f"skip: {rec['reason'].split(':')[-1].strip()} |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | — | ERROR | | | | | | |")
            continue
        r = rec["roofline"]
        dom = r["dominant"].replace("_s", "")
        rows.append(
            f"| {arch} | {shape} | {rec['production']['kind']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{dom}** | "
            f"{r['useful_ratio']:.2f} | {r['peak_hbm_gb']:.1f}GB | "
            f"{'yes' if r['fits_16gb'] else 'no'} |")
    return "\n".join(rows)


def dryrun_table(ledger) -> str:
    rows = ["| arch | shape | pod compile | multipod compile | coll ops | "
            "AG | AR | RS | A2A | CP |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(ledger):
        rec = ledger[key]
        arch, shape = key.split("|")
        if rec.get("status") != "ok":
            continue
        p = rec["production"]
        c = rec.get("production", {}).get("collectives", {})
        mp = rec.get("multipod", {})
        mp_s = (f"{mp.get('compile_s', '—')}s"
                if "compile_s" in mp else "ERR")
        rows.append(
            f"| {arch} | {shape} | {p['compile_s']}s | {mp_s} | "
            f"{p['per_device']['collective_ops']} | "
            f"{fmt_b(c.get('all-gather', 0))} | "
            f"{fmt_b(c.get('all-reduce', 0))} | "
            f"{fmt_b(c.get('reduce-scatter', 0))} | "
            f"{fmt_b(c.get('all-to-all', 0))} | "
            f"{fmt_b(c.get('collective-permute', 0))} |")
    return "\n".join(rows)


def perf_table(perf) -> str:
    rows = ["| variant | compute | memory | collective | dominant | "
            "HBM/dev | useful |",
            "|---|---|---|---|---|---|---|"]
    for rec in perf:
        if rec.get("status") != "ok":
            rows.append(f"| {rec['label']} | ERROR: {rec.get('error','')[:60]} | | | | | |")
            continue
        r = rec["roofline"]
        rows.append(
            f"| {rec['label']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{rec['peak_hbm_gb']:.1f}GB | {rec['useful_ratio']:.2f} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/ledger.json"
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):
        print(perf_table(data))
        return
    print("## Roofline\n")
    print(roofline_table(data))
    print("\n## Dry-run collectives\n")
    print(dryrun_table(data))


if __name__ == "__main__":
    main()
